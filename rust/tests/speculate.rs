//! Self-speculative decoding: the token-identity and rollback gates.
//!
//! The whole feature rests on two properties, and this harness pins
//! both end to end:
//!
//! - **Rollback is invisible.** Truncating a paged KV row after a
//!   rejected draft must restore the arena's invariants *and* the
//!   bits: re-decoding from the truncated state is bit-identical to
//!   never having drafted (property-tested over random block sizes,
//!   draft depths and mismatch positions).
//! - **Speculation is invisible.** Greedy speculative decode emits
//!   tokens identical to the master decoding alone — for random
//!   prompts/budgets/k, for the degenerate drafter == master edge
//!   (which must accept everything), for rank-0/nnz-0 garbage drafters
//!   (which must reject and roll back, never panic), and at the server
//!   level through the continuous scheduler with mid-decode admission.

use std::sync::mpsc::channel;
use std::time::Duration;

use salaad::config::ModelConfig;
use salaad::runtime::{KvCache, ModelParams, PackedPrompts, Runtime};
use salaad::serve::{Request, Response, Server, ServerOptions};
use salaad::slr::{BlockCuts, SlrBlock};
use salaad::tensor::Tensor;
use salaad::util::{prop, Rng};

fn tiny_cfg() -> ModelConfig {
    ModelConfig::from_geometry("tiny", 32, 8, 1, 2, 16, 24, 2)
}

/// A tiny server over synthetic developed blocks (attention
/// projections only), block_tokens 4 so every decode crosses block
/// boundaries.
fn tiny_server(rt: &Runtime, fracs: &[f64], max_batch: usize)
               -> Server<'_> {
    let cfg = tiny_cfg();
    let params = cfg.init_params(0);
    let mut blocks = Vec::new();
    let mut idx = Vec::new();
    for name in cfg.blocks(true, false) {
        let shape = cfg.shape_of(&name).unwrap().to_vec();
        blocks.push(SlrBlock::random(&name, shape[0], shape[1], 3,
                                     0.1, 0));
        idx.push(cfg.param_index(&name).unwrap());
    }
    Server::new(rt, cfg, &params, &blocks, &idx, fracs,
                ServerOptions { max_batch,
                                max_wait: Duration::from_millis(2),
                                kappa: 0.7,
                                block_tokens: 4 })
        .unwrap()
}

fn assert_bits_equal(a: &Tensor, b: &Tensor, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}: shape mismatch");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(),
                   "{what}: element {i} diverged ({x} vs {y})");
    }
}

/// Pre-queue a deterministic schedule, drain the server, and return
/// responses sorted by id.
fn run_schedule(server: &mut Server,
                schedule: &[(u64, Vec<u32>, usize, usize)])
                -> Vec<Response> {
    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    for (id, prompt, max_new, budget) in schedule {
        req_tx.send(Request::new(*id, prompt.clone(), *max_new,
                                 *budget))
            .unwrap();
    }
    drop(req_tx);
    server.run(req_rx, resp_tx).unwrap();
    let mut got: Vec<Response> = resp_rx.iter().collect();
    got.sort_by_key(|r| r.id);
    got
}

/// The rollback primitive itself: after feeding a row k junk tokens
/// (a rejected draft), `truncate_row` back to the pre-draft length
/// must (a) keep the arena's block accounting invariants, (b) report
/// the pre-draft length, and (c) make every subsequent decode step
/// bit-identical to a run that never drafted — across random block
/// sizes, prompt lengths, draft depths and positions.
#[test]
fn truncate_after_reject_restores_invariants_and_bits() {
    prop::check("spec_truncate_restores_bits", 10, |rng| {
        // `Runtime` holds a `Box<dyn Backend>` (not RefUnwindSafe), so
        // everything is built inside the closure.
        let rt = Runtime::native();
        let cfg = tiny_cfg();
        let params =
            ModelParams::from_dense(&cfg.init_params(rng.next_below(1 << 20)));
        let bsz = prop::dim(rng, 1, 8);
        let plen = prop::dim(rng, 2, 6);
        let n1 = prop::dim(rng, 1, 4); // decode steps before the draft
        let k = prop::dim(rng, 1, 5);  // junk draft positions
        let n2 = prop::dim(rng, 1, 5); // decode steps after rollback
        // plen + n1 + k + n2 ≤ 20 < seq_len 24: never out of headroom.
        let vocab = cfg.vocab as u64;
        let prompt: Vec<i32> = (0..plen)
            .map(|_| rng.next_below(vocab) as i32)
            .collect();
        // One shared token script so reference and subject feed
        // identical inputs at every step.
        let script: Vec<i32> = (0..n1 + n2)
            .map(|_| rng.next_below(vocab) as i32)
            .collect();
        let junk: Vec<i32> = (0..k)
            .map(|_| rng.next_below(vocab) as i32)
            .collect();
        let pack = PackedPrompts::equal(&prompt, 1).unwrap();

        // Reference: never drafts.
        let mut rcache = KvCache::with_block_size(&cfg, 1, bsz);
        rt.prefill_into(&cfg, &params, &mut rcache, &pack, &[0])
            .unwrap();
        let ref_logits: Vec<Tensor> = script.iter()
            .map(|&tok| rt.decode_rows(&cfg, &params, &mut rcache,
                                       &[tok], &[0])
                .unwrap())
            .collect();

        // Subject: same start, then a rejected k-token draft.
        let mut cache = KvCache::with_block_size(&cfg, 1, bsz);
        rt.prefill_into(&cfg, &params, &mut cache, &pack, &[0])
            .unwrap();
        for (j, &tok) in script[..n1].iter().enumerate() {
            let got = rt.decode_rows(&cfg, &params, &mut cache, &[tok],
                                     &[0])
                .unwrap();
            assert_bits_equal(&got, &ref_logits[j],
                              &format!("pre-draft step {j}"));
        }
        let len_before = cache.row_len(0);
        assert_eq!(len_before, plen + n1);
        let blocks_before = cache.blocks_in_use();
        for &tok in &junk {
            rt.decode_rows(&cfg, &params, &mut cache, &[tok], &[0])
                .unwrap();
        }
        assert_eq!(cache.row_len(0), len_before + k);

        // Reject everything: roll back to the pre-draft state.
        cache.truncate_row(0, len_before);
        cache.check_invariants()
            .unwrap_or_else(|e| panic!("arena invariants broken after \
                                        truncate: {e}"));
        assert_eq!(cache.row_len(0), len_before,
                   "truncate_row did not restore the length");
        assert!(cache.blocks_in_use() <= blocks_before + 1,
                "truncate kept the draft's surplus blocks");

        // Resuming must be bit-identical to never having drafted —
        // including the steps that overwrite the junk's recycled
        // positions.
        for (j, &tok) in script[n1..].iter().enumerate() {
            let got = rt.decode_rows(&cfg, &params, &mut cache, &[tok],
                                     &[0])
                .unwrap();
            assert_bits_equal(&got, &ref_logits[n1 + j],
                              &format!("post-rollback step {j}"));
        }
    });
}

/// Random prompts, budgets, drafter fractions and draft depths:
/// speculative decode must emit exactly `generate_cached`'s tokens and
/// keep its counters balanced.
#[test]
fn speculative_decode_is_token_identical_for_random_inputs() {
    prop::check("speculative_token_identity", 8, |rng| {
        let rt = Runtime::native();
        let server = tiny_server(&rt, &[0.3, 0.6], 4);
        let k = prop::dim(rng, 1, 6);
        let frac = rng.next_range_f64(0.0, 0.9);
        let drafter = server.carve_drafter(Some(frac)).unwrap();
        let vi = rng.next_below(server.variants.len() as u64) as usize;
        let variant = &server.variants[vi];
        let max_new = prop::dim(rng, 1, 12);
        let raw: Vec<u32> = (0..prop::dim(rng, 1, 10))
            .map(|_| rng.next_below(32) as u32)
            .collect();
        let prompt = server.prepare_prompt(&raw, max_new);
        let spec = server
            .generate_speculative(variant, &drafter, &prompt, max_new,
                                  k)
            .unwrap();
        let solo = server
            .generate_cached(variant, &[prompt], &[max_new])
            .unwrap();
        assert_eq!(spec.tokens, solo[0],
                   "speculation changed the tokens (k={k}, \
                    frac={frac:.3}, variant {vi})");
        assert!(spec.counters.consistent(),
                "drafted {} != accepted {} + rejected {}",
                spec.counters.drafted, spec.counters.accepted,
                spec.counters.rejected);
        assert!(spec.counters.drafted > 0);
        assert!(spec.counters.rounds > 0);
    });
}

/// Degenerate drafter == master: every draft is the master's own
/// argmax, so the verify pass must accept everything — zero rejects,
/// zero rollback. This pins the normative bit-identity between one
/// multi-token `extend_rows` pass and k sequential `decode_rows`
/// steps: a single rounding difference would surface as a reject.
#[test]
fn drafter_equal_to_master_accepts_every_draft() {
    let rt = Runtime::native();
    let server = tiny_server(&rt, &[0.5], 4);
    let full = server.variants.last().unwrap();
    let drafter = server.carve_variant(full.cuts.clone()).unwrap();
    let prompt = server.prepare_prompt(&[3, 1, 4, 1, 5], 12);
    let spec = server
        .generate_speculative(full, &drafter, &prompt, 12, 4)
        .unwrap();
    let solo = server
        .generate_cached(full, &[prompt], &[12])
        .unwrap();
    assert_eq!(spec.tokens, solo[0]);
    assert_eq!(spec.tokens.len(), 12);
    let c = spec.counters;
    assert!(c.consistent());
    assert_eq!(c.rejected, 0,
               "a drafter identical to the master was rejected: \
                extend_rows diverged from decode_rows");
    assert_eq!(c.rollback_tokens, 0);
    assert_eq!(c.accepted, c.drafted);
    assert!(c.drafted > 0);
    // Full acceptance means k+1 tokens per round (+1 for the prefill
    // token): far fewer verify rounds than tokens.
    assert!(c.rounds < spec.tokens.len() as u64);
}

/// Worst-case drafters must degrade gracefully, never corrupt output:
/// a rank-0/nnz-0 drafter (its SLR blocks vanish entirely) and a
/// drafter with a zeroed head (a constant context-independent
/// prediction) both keep token identity; the constant drafter's
/// mismatches exercise the reject-and-rollback path deterministically.
#[test]
fn garbage_drafters_force_rollback_without_breaking_identity() {
    let rt = Runtime::native();
    let server = tiny_server(&rt, &[0.5], 4);
    let full = server.variants.last().unwrap();
    let prompt = server.prepare_prompt(&[2, 7, 1, 8, 2, 8], 10);
    let solo = server
        .generate_cached(full, &[prompt.clone()], &[10])
        .unwrap();

    // Edge 1: all cuts zero — the cheapest view the spectrum can
    // express. Must not panic, must not change tokens.
    let zero_cuts =
        vec![BlockCuts { rank_k: 0, nnz_cut: 0 };
             server.masters().len()];
    let zeroed = server.carve_variant(zero_cuts).unwrap();
    let spec = server
        .generate_speculative(full, &zeroed, &prompt, 10, 4)
        .unwrap();
    assert_eq!(spec.tokens, solo[0],
               "rank-0/nnz-0 drafter changed the tokens");
    assert!(spec.counters.consistent());

    // Edge 2: zeroed drafter head — every logit row is all-equal, so
    // the drafter predicts one fixed index regardless of context
    // (`argmax_logit` is deterministic on ties). Unless the master
    // emits exactly that token at every drafted position, the verify
    // pass must reject at least once and roll both caches back;
    // tokens still must not move.
    let mut const_drafter = server.carve_variant(
        server.variants.last().unwrap().cuts.clone())
        .unwrap();
    let hidx = tiny_cfg().param_index("lm_head").unwrap();
    let hshape = tiny_cfg().shape_of("lm_head").unwrap().to_vec();
    const_drafter.params.values[hidx] =
        salaad::runtime::ParamValue::Dense(std::sync::Arc::new(
            Tensor::zeros(&hshape)));
    let spec = server
        .generate_speculative(full, &const_drafter, &prompt, 10, 4)
        .unwrap();
    assert_eq!(spec.tokens, solo[0],
               "constant drafter changed the tokens");
    let c = spec.counters;
    assert!(c.consistent());
    // Position 0 comes from the prefill, so only tokens 1.. were ever
    // draft-covered.
    let const_tok =
        salaad::serve::argmax_logit(&vec![0.0f32; 32]) as u32;
    if solo[0][1..].iter().any(|&t| t != const_tok) {
        assert!(c.rejected >= 1,
                "a garbage drafter was never rejected");
        assert!(c.acceptance_rate() < 1.0);
    }
}

/// Server-level identity gate: the continuous scheduler with
/// speculation enabled — drafter arena mirroring the master arena,
/// group verify rounds, mid-decode admission interleaving — must
/// deliver exactly the tokens of a plain run of the identical
/// schedule.
#[test]
fn continuous_scheduler_speculation_is_token_invisible() {
    let rt = Runtime::native();
    let mut server = tiny_server(&rt, &[0.4, 0.7], 3);
    // 10 mixed-everything requests over 3 slots: varied prompt
    // lengths, staggered budgets (one long row pins its slot so later
    // admissions are mid-decode), and budgets snapping to different
    // variants so verify rounds run per variant group.
    let mut rng = Rng::new(7);
    let n_var = server.variants.len();
    let schedule: Vec<(u64, Vec<u32>, usize, usize)> = (0..10u64)
        .map(|i| {
            let plen = 2 + (i as usize * 3) % 9;
            let max_new = if i == 0 { 12 } else { 1 + (i as usize * 5) % 6 };
            let prompt: Vec<u32> = (0..plen)
                .map(|_| rng.next_below(32) as u32)
                .collect();
            let budget = if i % 3 == 0 { 0 } else {
                server.variants[i as usize % n_var].params_count
            };
            (i, prompt, max_new, budget)
        })
        .collect();

    let plain = run_schedule(&mut server, &schedule);
    assert_eq!(plain.len(), 10);
    assert_eq!(server.stats.spec.drafted, 0,
               "plain run must not draft");
    assert!(server.stats.spec_latency_ms.is_empty());

    server.enable_speculation(3, None).unwrap();
    assert!(server.speculation().is_some());
    let spec = run_schedule(&mut server, &schedule);
    assert_eq!(spec.len(), 10);
    for (p, s) in plain.iter().zip(&spec) {
        assert_eq!(p.id, s.id);
        assert_eq!(p.tokens, s.tokens,
                   "speculation changed request {}'s tokens", p.id);
        assert_eq!(p.served_params, s.served_params,
                   "speculation changed request {}'s routing", p.id);
    }
    let st = &server.stats;
    assert!(st.spec.drafted > 0, "speculative run never drafted");
    assert!(st.spec.consistent(),
            "drafted {} != accepted {} + rejected {}",
            st.spec.drafted, st.spec.accepted, st.spec.rejected);
    assert!(st.acceptance_rate() > 0.0,
            "the shared-store drafter never agreed with its master");
    assert_eq!(st.spec_latency_ms.len(), 10,
               "every speculative request must record a latency \
                sample");
    assert!(st.spec_latency_pct(0.99) >= st.spec_latency_pct(0.5));
    // Composition with continuous batching: admission still happened
    // mid-decode, and both arenas drained cleanly.
    assert!(st.admitted_mid_decode >= 1,
            "speculation must not serialize the scheduler");
    assert_eq!(st.arena_blocks_in_use, 0,
               "retired rows must return master and drafter blocks");

    // Speculation can be switched back off on the live server.
    server.disable_speculation();
    assert!(server.speculation().is_none());
    let drafted_before = server.stats.spec.drafted;
    let again = run_schedule(&mut server, &schedule);
    for (p, a) in plain.iter().zip(&again) {
        assert_eq!(p.tokens, a.tokens);
    }
    assert_eq!(server.stats.spec.drafted, drafted_before,
               "disabled speculation still drafted");
}

/// `enable_speculation` argument validation and drafter nesting: an
/// explicit `--draft-frac` drafter never out-ranks the smallest
/// admitted variant (its cuts are clamped under it block-wise).
#[test]
fn drafter_carving_nests_under_the_smallest_variant() {
    let rt = Runtime::native();
    let mut server = tiny_server(&rt, &[0.3, 0.6], 4);
    assert!(server.enable_speculation(0, None).is_err(),
            "k = 0 must be rejected");
    // Even a frac *smaller* than every admitted budget (an expensive
    // drafter) is clamped under the smallest variant.
    for frac in [0.0, 0.2, 0.5, 0.9, 2.0] {
        let drafter = server.carve_drafter(Some(frac)).unwrap();
        let smallest = &server.variants[0];
        for (d, m) in drafter.cuts.iter().zip(&smallest.cuts) {
            assert!(d.rank_k <= m.rank_k && d.nnz_cut <= m.nnz_cut,
                    "drafter cut {d:?} out-ranks verifier cut {m:?} \
                     at frac {frac}");
        }
        assert!(drafter.params_count <= smallest.params_count);
    }
    // Default drafter: the smallest admitted variant's own cuts.
    let default = server.carve_drafter(None).unwrap();
    assert_eq!(default.cuts, server.variants[0].cuts);
    // And the drafter is zero-copy: views over the same masters, so
    // its marginal bytes are metadata-scale, far below the store.
    assert!(default.marginal_bytes() * 10
                < server.master_store_bytes(),
            "drafter marginal {}B not metadata-scale vs master {}B",
            default.marginal_bytes(), server.master_store_bytes());
    server.enable_speculation(4, Some(0.8)).unwrap();
    assert_eq!(server.speculation().unwrap().k, 4);
}
