//! Nested zero-copy variants: the whole-model equivalence and
//! accounting gates of the shared-factor-store refactor.
//!
//! - View-variant logits must be **bit-exact** against the same budget
//!   materialized the pre-refactor way (contiguous truncated factors
//!   evaluated by the tiled GEMM path) — on nano and micro, for every
//!   builtin budget fraction, including the `rank_k = 0` and
//!   `nnz_cut = 0` edges.
//! - Greedy decode over a view variant must emit tokens identical to
//!   the materialized variant's decode.
//! - `admit_budget` must carve budgets on a *live* server (traffic
//!   before and after) with marginal cost <10% of the master store.

use std::sync::Arc;

use salaad::config::ModelConfig;
use salaad::runtime::{ModelParams, PackedPrompts, ParamValue, Runtime};
use salaad::serve::{argmax_logit, Request, Server, ServerOptions,
                    BUILTIN_BUDGET_FRACS};
use salaad::slr::{BlockCuts, FactoredLinear, SlrBlock};

/// Synthetic developed SLR blocks over the selected 2-D parameters,
/// paired with their indices into `cfg.params`.
fn synthetic_blocks(cfg: &ModelConfig, rank: usize, density: f64)
                    -> (Vec<SlrBlock>, Vec<usize>) {
    let mut blocks = Vec::new();
    let mut idx = Vec::new();
    for name in cfg.blocks(true, true) {
        let shape = cfg.shape_of(&name).unwrap().to_vec();
        blocks.push(SlrBlock::random(&name, shape[0], shape[1], rank,
                                     density, 11));
        idx.push(cfg.param_index(&name).unwrap());
    }
    (blocks, idx)
}

fn fixed_tokens(cfg: &ModelConfig, n: usize) -> Vec<i32> {
    (0..n).map(|i| ((i * 29 + 7) % cfg.vocab) as i32).collect()
}

/// The pre-refactor representation of a parameter set: every factored
/// view copied out into a standalone contiguous prefix (evaluated by
/// the tiled `matmul`/`matmul_nt`/`spmm_t` path), dense entries
/// shared as-is.
fn materialized(params: &ModelParams) -> ModelParams {
    ModelParams {
        values: params.values.iter()
            .map(|v| match v {
                ParamValue::Factored(f) => {
                    ParamValue::Factored(f.materialize())
                }
                dense => dense.clone(),
            })
            .collect(),
    }
}

fn assert_bits_equal(a: &salaad::tensor::Tensor,
                     b: &salaad::tensor::Tensor, what: &str) {
    assert_eq!(a.shape, b.shape, "{what}: shape mismatch");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(),
                   "{what}: element {i} diverged ({x} vs {y})");
    }
}

/// Greedy KV-cached decode straight on the runtime seam (no server
/// plumbing), so both representations run the identical code path.
fn greedy_decode(rt: &Runtime, cfg: &ModelConfig, params: &ModelParams,
                 prompt: &[i32], max_new: usize) -> Vec<u32> {
    let pack = PackedPrompts::equal(prompt, 1).unwrap();
    let (logits, mut cache) = rt.prefill(cfg, params, &pack).unwrap();
    let v = cfg.vocab;
    let plen = prompt.len();
    let mut out = Vec::with_capacity(max_new);
    let mut last =
        argmax_logit(&logits.data[(plen - 1) * v..plen * v]) as i32;
    out.push(last as u32);
    for _ in 1..max_new.min(cfg.seq_len - plen) {
        let step = rt.decode_step(cfg, params, &mut cache, &[last])
            .unwrap();
        last = argmax_logit(step.row(0)) as i32;
        out.push(last as u32);
    }
    out
}

#[test]
fn view_variants_are_bit_exact_vs_materialized_on_builtin_fracs() {
    let rt = Runtime::native();
    for scale in ["nano", "micro"] {
        let cfg = rt.model_config(scale).unwrap();
        let (blocks, idx) = synthetic_blocks(&cfg, 8, 0.05);
        let params = cfg.init_params(2);
        let server = Server::new(&rt, cfg.clone(), &params, &blocks,
                                 &idx, BUILTIN_BUDGET_FRACS,
                                 ServerOptions::default())
            .unwrap();
        // Full + one per builtin frac (no accidental dedup at these
        // scales).
        assert_eq!(server.variants.len(),
                   1 + BUILTIN_BUDGET_FRACS.len(),
                   "{scale}: unexpected variant dedup");
        let tokens = fixed_tokens(&cfg, cfg.seq_len);
        for variant in &server.variants {
            let mat = materialized(&variant.params);
            let got = rt.forward_logits_model(&cfg, &variant.params,
                                              &tokens, 1).unwrap();
            let want = rt.forward_logits_model(&cfg, &mat, &tokens, 1)
                .unwrap();
            assert_bits_equal(&got, &want,
                              &format!("{scale} variant {} logits",
                                       variant.params_count));
            // Decode: views and materialized copies emit identical
            // tokens (the pre-refactor serving behavior, preserved).
            let prompt = &tokens[..8];
            let a = greedy_decode(&rt, &cfg, &variant.params, prompt, 6);
            let b = greedy_decode(&rt, &cfg, &mat, prompt, 6);
            assert_eq!(a, b,
                       "{scale} variant {}: view decode diverged from \
                        materialized decode",
                       variant.params_count);
            assert_eq!(a.len(), 6);
        }
    }
}

#[test]
fn zero_cut_views_match_materialized_edges() {
    let rt = Runtime::native();
    let cfg = rt.model_config("nano").unwrap();
    let (blocks, idx) = synthetic_blocks(&cfg, 6, 0.05);
    let params = cfg.init_params(4);
    let server = Server::new(&rt, cfg.clone(), &params, &blocks, &idx,
                             &[], ServerOptions::default()).unwrap();
    let full = &server.variants[0];
    let tokens = fixed_tokens(&cfg, cfg.seq_len);
    // Three edge spectra: rank_k = 0 (pure sparse), nnz_cut = 0 (pure
    // low-rank), and both 0 (the block vanishes entirely).
    for (keep_rank, keep_nnz, label) in [
        (false, true, "rank0"),
        (true, false, "nnz0"),
        (false, false, "both0"),
    ] {
        let mut values = full.params.values.clone();
        for (i, store) in server.masters() {
            let rk = if keep_rank { store.rank_max() } else { 0 };
            let nq = if keep_nnz { store.nnz_max() } else { 0 };
            values[*i] = ParamValue::Factored(
                FactoredLinear::view(Arc::clone(store), rk, nq)
                    .unwrap());
        }
        let view_params = ModelParams { values };
        let mat = materialized(&view_params);
        let got = rt.forward_logits_model(&cfg, &view_params, &tokens,
                                          1).unwrap();
        let want = rt.forward_logits_model(&cfg, &mat, &tokens, 1)
            .unwrap();
        assert_bits_equal(&got, &want, &format!("{label} logits"));
    }
}

#[test]
fn admit_budget_round_trips_on_a_live_server() {
    let rt = Runtime::native();
    let cfg = rt.model_config("nano").unwrap();
    let (blocks, idx) = synthetic_blocks(&cfg, 8, 0.05);
    let params = cfg.init_params(6);
    let mut server = Server::new(&rt, cfg.clone(), &params, &blocks,
                                 &idx, &[0.6],
                                 ServerOptions::default()).unwrap();

    // Traffic before the admit.
    let full_count = server.variants.last().unwrap().params_count;
    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
    req_tx.send(Request::new(0, vec![1, 2, 3], 2, 0)).unwrap();
    drop(req_tx);
    server.run(req_rx, resp_tx).unwrap();
    let first: Vec<_> = resp_rx.iter().collect();
    assert_eq!(first.len(), 1);
    assert_eq!(first[0].served_params, full_count);

    // Carve a mid-spectrum budget on the live server: no rebuild, no
    // weight copies, marginal <10% of the master store.
    let shared_before = server.stats.shared_bytes;
    let vi = server.admit_budget(0.3).unwrap();
    let admitted = server.variants[vi].params_count;
    assert_eq!(server.stats.shared_bytes, shared_before,
               "admit copied weights");
    assert!(server.variants[vi].marginal_bytes() * 10
                < server.master_store_bytes());

    // Traffic after the admit snaps onto the new point.
    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
    req_tx.send(Request::new(1, vec![4, 5, 6], 2, admitted)).unwrap();
    drop(req_tx);
    server.run(req_rx, resp_tx).unwrap();
    let second: Vec<_> = resp_rx.iter().collect();
    assert_eq!(second.len(), 1);
    assert_eq!(second[0].served_params, admitted,
               "request did not snap to the runtime-admitted budget");
    assert!(!second[0].over_budget);
    assert_eq!(second[0].tokens.len(), 2);
    // Per-variant counters saw both phases.
    assert_eq!(server.stats.served_by_variant.get(&full_count),
               Some(&1));
    assert_eq!(server.stats.served_by_variant.get(&admitted), Some(&1));

    // The admitted view is bit-exact against its materialization too.
    let tokens = fixed_tokens(&cfg, cfg.seq_len);
    let mat = materialized(&server.variants[vi].params);
    let got = rt.forward_logits_model(&cfg, &server.variants[vi].params,
                                      &tokens, 1).unwrap();
    let want = rt.forward_logits_model(&cfg, &mat, &tokens, 1).unwrap();
    assert_bits_equal(&got, &want, "admitted variant logits");
}

/// Self-speculative decoding across the whole budget spectrum: every
/// (verifier variant × drafter cut) pairing — the default drafter, one
/// per builtin budget fraction, the degenerate drafter == verifier,
/// and the rank-0/nnz-0 edge — must emit tokens identical to the
/// verifier decoding alone, at nano and micro. The drafters are all
/// prefix views over the same shared master stores, so this is also
/// the zero-extra-weights claim exercised end to end.
#[test]
fn speculative_decode_matches_solo_across_the_budget_spectrum() {
    let rt = Runtime::native();
    for scale in ["nano", "micro"] {
        let cfg = rt.model_config(scale).unwrap();
        let (blocks, idx) = synthetic_blocks(&cfg, 8, 0.05);
        let params = cfg.init_params(2);
        let server = Server::new(&rt, cfg.clone(), &params, &blocks,
                                 &idx, BUILTIN_BUDGET_FRACS,
                                 ServerOptions::default())
            .unwrap();
        let raw: Vec<u32> = fixed_tokens(&cfg, 8).iter()
            .map(|&t| t as u32)
            .collect();
        let prompt = server.prepare_prompt(&raw, 10);

        // Drafter pool: the default (smallest admitted variant's own
        // cuts) plus one drafter per builtin budget fraction — every
        // one a zero-copy view set sharing the verifier's masters.
        let mut drafters = vec![("default".to_string(),
                                 server.carve_drafter(None).unwrap())];
        for &f in BUILTIN_BUDGET_FRACS {
            drafters.push((format!("frac{f}"),
                           server.carve_drafter(Some(f)).unwrap()));
        }
        for (_, d) in &drafters {
            assert!(d.marginal_bytes() * 10
                        < server.master_store_bytes(),
                    "{scale}: drafter is not metadata-scale");
        }

        for variant in &server.variants {
            let solo = server
                .generate_cached(variant, &[prompt.clone()], &[10])
                .unwrap();
            for (label, drafter) in &drafters {
                for k in [2usize, 5] {
                    let spec = server
                        .generate_speculative(variant, drafter,
                                              &prompt, 10, k)
                        .unwrap();
                    assert_eq!(
                        spec.tokens, solo[0],
                        "{scale} variant {} drafter {label} k={k}: \
                         speculation changed the tokens",
                        variant.params_count);
                    assert!(spec.counters.consistent(),
                            "{scale} drafter {label}: counters do not \
                             balance");
                    assert!(spec.counters.drafted > 0);
                }
            }
        }

        if scale != "nano" {
            continue;
        }
        // Degenerate edges, pinned at nano. Drafter == verifier: the
        // verify pass must accept every draft (a single reject would
        // mean extend_rows diverged bit-wise from decode_rows).
        let full = server.variants.last().unwrap();
        let twin = server.carve_variant(full.cuts.clone()).unwrap();
        let spec = server
            .generate_speculative(full, &twin, &prompt, 10, 4)
            .unwrap();
        let solo = server
            .generate_cached(full, &[prompt.clone()], &[10])
            .unwrap();
        assert_eq!(spec.tokens, solo[0]);
        assert_eq!(spec.counters.rejected, 0,
                   "drafter == master must accept everything");
        assert_eq!(spec.counters.rollback_tokens, 0);
        // rank-0/nnz-0 drafter: the blocks vanish entirely; decoding
        // must fall through gracefully (identity holds, no panic).
        let zero = server
            .carve_variant(vec![BlockCuts { rank_k: 0, nnz_cut: 0 };
                                server.masters().len()])
            .unwrap();
        let spec = server
            .generate_speculative(full, &zero, &prompt, 10, 4)
            .unwrap();
        assert_eq!(spec.tokens, solo[0],
                   "rank-0/nnz-0 drafter changed the tokens");
        assert!(spec.counters.consistent());
    }
}

#[test]
fn spectrum_of_budgets_is_nearly_free_at_nano() {
    let rt = Runtime::native();
    let cfg = rt.model_config("nano").unwrap();
    let (blocks, idx) = synthetic_blocks(&cfg, 8, 0.05);
    let params = cfg.init_params(0);
    let mut server = Server::new(&rt, cfg, &params, &blocks, &idx,
                                 BUILTIN_BUDGET_FRACS,
                                 ServerOptions::default()).unwrap();
    let shared = server.stats.shared_bytes;
    for frac in [0.15, 0.45, 0.75, 0.9] {
        server.admit_budget(frac).unwrap();
    }
    assert!(server.variants.len() >= 5);
    assert_eq!(server.stats.shared_bytes, shared,
               "admits changed the shared footprint");
    // Serving the whole spectrum costs ≤ master store + V·O(blocks):
    // the aggregate marginal is <10% of the master store, and far
    // below what per-variant copies would have resided.
    assert!(server.stats.marginal_bytes * 10
                < server.master_store_bytes(),
            "spectrum marginal {}B not below 10% of master {}B",
            server.stats.marginal_bytes, server.master_store_bytes());
    let old_world: usize = server.variants.iter()
        .map(|v| v.materialized_bytes()).sum();
    assert!(shared + server.stats.marginal_bytes < old_world);
}
