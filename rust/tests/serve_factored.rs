//! Factored-serving integration: dense-vs-factored logits equivalence
//! across every builtin scale, resident-memory accounting, KV-cached
//! decode equivalence with the full-recompute loop, timed checks that
//! cached decode beats the seed O(T²) loop, and the ragged-packing
//! gates — mixed-length packs must emit tokens bit-identical to solo
//! decodes and beat G separate prefills on wall-clock.

use std::time::{Duration, Instant};

use salaad::config::ModelConfig;
use salaad::runtime::{ModelParams, ParamValue, Runtime};
use salaad::serve::{Server, ServerOptions};
use salaad::slr::SlrBlock;
use salaad::util::Rng;

/// Synthetic developed SLR blocks over the selected 2-D parameters
/// (embed + per-layer projections + lm_head), paired with their indices
/// into `cfg.params`.
fn synthetic_blocks(cfg: &ModelConfig, rank: usize, density: f64)
                    -> (Vec<SlrBlock>, Vec<usize>) {
    let mut blocks = Vec::new();
    let mut idx = Vec::new();
    for name in cfg.blocks(true, true) {
        let shape = cfg.shape_of(&name).unwrap().to_vec();
        blocks.push(SlrBlock::random(&name, shape[0], shape[1], rank,
                                     density, 7));
        idx.push(cfg.param_index(&name).unwrap());
    }
    (blocks, idx)
}

/// (dense params with X̂ substituted, same set with factors kept).
fn dense_and_factored(cfg: &ModelConfig, blocks: &[SlrBlock],
                      idx: &[usize])
                      -> (Vec<salaad::tensor::Tensor>, ModelParams) {
    let mut dense = cfg.init_params(3);
    let mut mp = ModelParams::from_dense(&dense);
    for (b, &i) in blocks.iter().zip(idx) {
        dense[i] = b.xhat();
        mp.values[i] = ParamValue::Factored(b.to_factored());
    }
    (dense, mp)
}

fn fixed_tokens(cfg: &ModelConfig, n: usize) -> Vec<i32> {
    (0..n).map(|i| ((i * 37 + 11) % cfg.vocab) as i32).collect()
}

#[test]
fn factored_logits_match_densified_xhat_on_every_builtin_config() {
    let rt = Runtime::native();
    for scale in ModelConfig::builtin_names() {
        let cfg = rt.model_config(scale).unwrap();
        let (blocks, idx) = synthetic_blocks(&cfg, 8, 0.05);
        let (dense, mp) = dense_and_factored(&cfg, &blocks, &idx);
        // The factored form must be strictly lighter than dense X̂.
        assert!(mp.resident_bytes() < mp.dense_bytes(),
                "{scale}: factored {}B not below dense {}B",
                mp.resident_bytes(), mp.dense_bytes());
        let tokens = fixed_tokens(&cfg, cfg.seq_len);
        let want = rt.forward_logits(&cfg, &dense, &tokens, 1).unwrap();
        let got = rt.forward_logits_model(&cfg, &mp, &tokens, 1).unwrap();
        assert_eq!(want.shape, got.shape);
        let diff: f32 = want.data.iter().zip(&got.data)
            .map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        assert!(diff < 1e-4,
                "{scale}: factored logits diverged by {diff}");
    }
}

#[test]
fn server_spectrum_resides_in_shared_store_plus_metadata() {
    let rt = Runtime::native();
    let cfg = rt.model_config("nano").unwrap();
    let (blocks, idx) = synthetic_blocks(&cfg, 12, 0.08);
    let params = cfg.init_params(0);
    let server = Server::new(&rt, cfg, &params, &blocks, &idx,
                             &[0.4, 0.7], ServerOptions::default())
        .unwrap();
    assert!(server.variants.len() >= 2);
    let small = &server.variants[0];
    assert!(small.n_factored() > 0,
            "compressed variant holds no factored views");
    // A standalone copy of the compressed variant would still beat
    // dense X̂ (the paper's per-variant memory claim)…
    assert!(small.materialized_bytes() < small.dense_bytes(),
            "standalone copy {}B not strictly below dense {}B",
            small.materialized_bytes(), small.dense_bytes());
    // …but the refactor's claim is stronger: the *whole spectrum*
    // resides in one shared store + per-variant metadata, below what
    // one-copy-per-variant used to cost.
    let old_world: usize = server.variants.iter()
        .map(|v| v.materialized_bytes()).sum();
    let new_world = server.stats.shared_bytes
        + server.stats.marginal_bytes;
    assert!(new_world < old_world,
            "shared spectrum {new_world}B not below per-variant copies \
             {old_world}B");
    // At nano scale the marginal cost is a rounding error: every
    // variant is under 10% of the master store.
    for v in &server.variants {
        assert!(v.marginal_bytes() * 10 < server.master_store_bytes(),
                "variant {} marginal {}B not below 10% of the {}B \
                 master store", v.params_count, v.marginal_bytes(),
                server.master_store_bytes());
    }
}

#[test]
fn cached_decode_emits_identical_tokens_to_full_recompute() {
    let rt = Runtime::native();
    let cfg = rt.model_config("nano").unwrap();
    let (blocks, idx) = synthetic_blocks(&cfg, 8, 0.05);
    let params = cfg.init_params(5);
    let server = Server::new(&rt, cfg, &params, &blocks, &idx, &[0.5],
                             ServerOptions::default()).unwrap();
    let prompts: [&[u32]; 3] =
        [&[3, 1, 4, 1, 5, 9, 2, 6], &[42], &[7; 20]];
    for variant in &server.variants {
        for prompt in prompts {
            let prepared = server.prepare_prompt(prompt, 16);
            let slow = server
                .generate_uncached(variant, &prepared, 16)
                .unwrap();
            let fast = server
                .generate_cached(variant, &[prepared.clone()], &[16])
                .unwrap();
            assert_eq!(slow, fast[0],
                       "cached decode diverged on prompt {prompt:?}");
            assert_eq!(slow.len(), 16);
        }
    }
}

#[test]
fn packed_prefill_matches_per_request_decode() {
    let rt = Runtime::native();
    let cfg = rt.model_config("nano").unwrap();
    let (blocks, idx) = synthetic_blocks(&cfg, 8, 0.05);
    let params = cfg.init_params(5);
    let server = Server::new(&rt, cfg, &params, &blocks, &idx, &[],
                             ServerOptions::default()).unwrap();
    let variant = server.variants.last().unwrap();
    let a = server.prepare_prompt(&[1, 2, 3, 4, 5, 6], 8);
    let b = server.prepare_prompt(&[9, 8, 7, 6, 5, 4], 8);
    let c = server.prepare_prompt(&[11, 12, 13, 14, 15, 16], 8);
    let packed = server
        .generate_cached(variant, &[a.clone(), b.clone(), c.clone()],
                         &[8, 8, 5])
        .unwrap();
    for (i, p) in [a, b, c].into_iter().enumerate() {
        let solo = server
            .generate_cached(variant, &[p], &[[8, 8, 5][i]])
            .unwrap();
        assert_eq!(packed[i], solo[0], "row {i} diverged in the pack");
    }
    assert_eq!(packed[2].len(), 5);
}

/// Ragged packed prefill + decode must emit tokens identical to a solo
/// decode of every row, across random prompt-length mixes on nano and
/// micro — the serving-level form of the runtime's bit-exactness
/// guarantee. Seeded like `util::prop`: a failure prints its seed.
#[test]
fn ragged_packs_emit_tokens_identical_to_solo_decode() {
    let rt = Runtime::native();
    for (scale, iters) in [("nano", 5u64), ("micro", 2)] {
        let cfg = rt.model_config(scale).unwrap();
        let t = cfg.seq_len;
        let (blocks, idx) = synthetic_blocks(&cfg, 6, 0.05);
        let params = cfg.init_params(9);
        let server = Server::new(&rt, cfg.clone(), &params, &blocks,
                                 &idx, &[], ServerOptions::default())
            .unwrap();
        let variant = server.variants.last().unwrap();
        for seed in 0..iters {
            let mut rng = Rng::named("ragged_pack", seed);
            // Seed 0 pins the edge mix (3 forced rows below); later
            // seeds draw 2..=4 random rows.
            let rows = if seed == 0 {
                3
            } else {
                2 + rng.next_below(3) as usize
            };
            let mut prompts = Vec::with_capacity(rows);
            let mut max_new = Vec::with_capacity(rows);
            for r in 0..rows {
                // Random length in 1..=t−1, with the edge rows forced
                // on the first seed: an all-pads-but-one row (len 1)
                // next to a maximal row (len t−1), plus an
                // empty-prompt row (prepare_prompt pads it).
                let raw: Vec<u32> = match (seed, r) {
                    (0, 0) => vec![3],
                    (0, 1) => (0..t as u32 - 1)
                        .map(|i| i % cfg.vocab as u32).collect(),
                    (0, 2) => Vec::new(),
                    _ => {
                        let plen =
                            1 + rng.next_below(t as u64 - 1) as usize;
                        (0..plen)
                            .map(|_| rng.next_below(cfg.vocab as u64)
                                as u32)
                            .collect()
                    }
                };
                let m = 1 + rng.next_below(4) as usize; // 1..=4 tokens
                prompts.push(server.prepare_prompt(&raw, m));
                max_new.push(m);
            }
            let packed = server
                .generate_cached(variant, &prompts, &max_new)
                .unwrap();
            for r in 0..rows {
                let solo = server
                    .generate_cached(variant, &[prompts[r].clone()],
                                     &[max_new[r]])
                    .unwrap();
                assert_eq!(
                    packed[r], solo[0],
                    "{scale} seed {seed} row {r} (len {} of mix {:?}): \
                     packed tokens diverged from solo decode",
                    prompts[r].len(),
                    prompts.iter().map(|p| p.len()).collect::<Vec<_>>());
            }
        }
    }
}

/// The throughput claim behind ragged packing: at 4 mixed-length
/// requests on nano, one packed prefill+decode must beat the 4
/// separate prefill+decodes the seed per-length grouping ran — by a
/// conservative 1.25× to stay flake-proof on noisy CI boxes (the
/// observed ratio is far larger, since the packed decode amortizes
/// every step across rows).
#[test]
fn ragged_pack_beats_separate_prefills_at_4_mixed_lengths() {
    let rt = Runtime::native();
    let cfg = rt.model_config("nano").unwrap();
    let t = cfg.seq_len;
    let (blocks, idx) = synthetic_blocks(&cfg, 8, 0.05);
    let params = cfg.init_params(1);
    let server = Server::new(&rt, cfg.clone(), &params, &blocks, &idx,
                             &[], ServerOptions::default()).unwrap();
    let variant = server.variants.last().unwrap();
    let prompts: Vec<Vec<u32>> = [t / 8, t / 4, t / 2, 3 * t / 4]
        .into_iter()
        .map(|plen| server.prepare_prompt(
            &(0..plen as u32).map(|i| i % cfg.vocab as u32)
                .collect::<Vec<u32>>(),
            16))
        .collect();
    let max_new = [16usize, 16, 16, 16];

    // Warm-up (thread pools, allocator) + correctness cross-check.
    let warm_packed = server
        .generate_cached(variant, &prompts, &max_new)
        .unwrap();
    for (r, p) in prompts.iter().enumerate() {
        let solo = server
            .generate_cached(variant, &[p.clone()], &[max_new[r]])
            .unwrap();
        assert_eq!(warm_packed[r], solo[0], "row {r} diverged");
    }

    let t0 = Instant::now();
    let _ = server.generate_cached(variant, &prompts, &max_new).unwrap();
    let packed = t0.elapsed();
    let t1 = Instant::now();
    for (r, p) in prompts.iter().enumerate() {
        let _ = server
            .generate_cached(variant, &[p.clone()], &[max_new[r]])
            .unwrap();
    }
    let separate = t1.elapsed();
    assert!(packed * 5 < separate * 4,
            "ragged pack ({packed:?}) not ≥1.25× faster than 4 \
             separate prefill+decodes ({separate:?})");
    // Sanity floor so a broken timer cannot vacuously pass.
    assert!(separate > Duration::from_micros(50));
}

#[test]
fn cached_decode_is_faster_than_full_recompute_for_32_tokens() {
    // The acceptance check for O(T) decode: 32 generated tokens on the
    // nano config. The uncached loop runs 32 full seq_len-length
    // forwards; the cached one runs one short prefill + 31 single
    // position steps, an ~T/1 work ratio per step — we only assert a
    // conservative 2x wall-clock win to stay robust on noisy CI boxes.
    let rt = Runtime::native();
    let cfg = rt.model_config("nano").unwrap();
    let (blocks, idx) = synthetic_blocks(&cfg, 8, 0.05);
    let params = cfg.init_params(1);
    let server = Server::new(&rt, cfg, &params, &blocks, &idx, &[],
                             ServerOptions::default()).unwrap();
    let variant = server.variants.last().unwrap();
    let prompt = server.prepare_prompt(&[5, 4, 3, 2, 1, 0, 1, 2], 32);

    // Warm-up both paths (thread pools, allocator).
    let warm_slow = server.generate_uncached(variant, &prompt, 4)
        .unwrap();
    let warm_fast = server
        .generate_cached(variant, &[prompt.clone()], &[4])
        .unwrap();
    assert_eq!(warm_slow, warm_fast[0]);

    let t0 = Instant::now();
    let slow = server.generate_uncached(variant, &prompt, 32).unwrap();
    let uncached = t0.elapsed();
    let t1 = Instant::now();
    let fast = server
        .generate_cached(variant, &[prompt.clone()], &[32])
        .unwrap();
    let cached = t1.elapsed();
    assert_eq!(slow, fast[0]);
    assert_eq!(slow.len(), 32);
    assert!(cached * 2 < uncached,
            "cached decode ({cached:?}) not measurably faster than the \
             full-recompute loop ({uncached:?}) for 32 tokens");
    // Sanity floor so a broken timer cannot vacuously pass.
    assert!(uncached > Duration::from_micros(50));
}
