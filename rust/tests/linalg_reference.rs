//! Numerical reference tests for the linalg substrate: small
//! hand-computed cases where every expected value is derived on paper,
//! complementing the property tests inside `src/linalg/`.

use salaad::linalg::{jacobi_svd, matmul, matmul_nt, matmul_tn,
                     reconstruct};
use salaad::tensor::Tensor;

#[test]
fn matmul_hand_computed_2x3_3x2() {
    let a = Tensor::new(vec![1., 2., 3., 4., 5., 6.], &[2, 3]);
    let b = Tensor::new(vec![7., 8., 9., 10., 11., 12.], &[3, 2]);
    let c = matmul(&a, &b);
    assert_eq!(c.shape, vec![2, 2]);
    // [1 2 3]·[7 9 11]^T-cols: row0 = (58, 64), row1 = (139, 154).
    assert_eq!(c.data, vec![58., 64., 139., 154.]);
}

#[test]
fn matmul_variants_hand_computed() {
    let a = Tensor::new(vec![1., 2., 3., 4.], &[2, 2]);
    let b = Tensor::new(vec![5., 6., 7., 8.], &[2, 2]);
    // A·Bᵀ: row0 = (1·5+2·6, 1·7+2·8) = (17, 23); row1 = (39, 53).
    assert_eq!(matmul_nt(&a, &b).data, vec![17., 23., 39., 53.]);
    // Aᵀ·B: col-dot form: [[1·5+3·7, 1·6+3·8], [2·5+4·7, 2·6+4·8]].
    assert_eq!(matmul_tn(&a, &b).data, vec![26., 30., 38., 44.]);
}

#[test]
fn svd_known_2x2_spectrum() {
    // A = [[3, 0], [4, 5]]: AᵀA = [[25, 20], [20, 25]], eigenvalues
    // 45 and 5, so σ = (√45, √5).
    let a = Tensor::new(vec![3., 0., 4., 5.], &[2, 2]);
    let svd = jacobi_svd(&a);
    assert!((svd.s[0] as f64 - 45f64.sqrt()).abs() < 1e-4,
            "σ1 {}", svd.s[0]);
    assert!((svd.s[1] as f64 - 5f64.sqrt()).abs() < 1e-4,
            "σ2 {}", svd.s[1]);
    // Frobenius identity: σ1² + σ2² = ‖A‖²_F = 9 + 16 + 25 = 50.
    let ss: f64 = svd.s.iter().map(|x| (*x as f64).powi(2)).sum();
    assert!((ss - 50.0).abs() < 1e-3);
    // Exact reconstruction for a full SVD.
    assert!(svd.reconstruct().dist_frob(&a) < 1e-4);
}

#[test]
fn svd_rank_one_matrix() {
    // [[2, 4], [1, 2]] = (2, 1)ᵀ · (1, 2): rank 1, σ1 = ‖A‖_F = 5.
    let a = Tensor::new(vec![2., 4., 1., 2.], &[2, 2]);
    let svd = jacobi_svd(&a);
    assert!((svd.s[0] - 5.0).abs() < 1e-4, "σ1 {}", svd.s[0]);
    assert!(svd.s[1].abs() < 1e-4, "σ2 {}", svd.s[1]);
    assert_eq!(svd.rank(1e-4), 1);
}

#[test]
fn svd_orthogonal_matrix_has_unit_spectrum() {
    // A rotation matrix: both singular values exactly 1.
    let (c, s) = (0.6f32, 0.8f32);
    let a = Tensor::new(vec![c, -s, s, c], &[2, 2]);
    let svd = jacobi_svd(&a);
    for sv in &svd.s {
        assert!((sv - 1.0).abs() < 1e-5, "spectrum {:?}", svd.s);
    }
}

#[test]
fn reconstruct_diag_scaling() {
    // U = I₂, s = (3, 2), V = I₂ → U diag(s) Vᵀ = diag(3, 2).
    let eye = Tensor::new(vec![1., 0., 0., 1.], &[2, 2]);
    let rec = reconstruct(&eye, &[3.0, 2.0], &eye);
    assert_eq!(rec.data, vec![3., 0., 0., 2.]);
}
