//! Integration tests for the pluggable runtime.
//!
//! The default build exercises the pure-Rust `NativeBackend` end to end
//! — backend selection, logits shapes, loss/eval consistency, gradient
//! sanity and the greedy-decode contract — with zero artifacts, so CI
//! always runs them. The original cross-language PJRT fixture tests
//! (Rust-initialized parameters fed into python-lowered HLO reproducing
//! JAX-recorded numbers) are preserved behind the `xla` feature at the
//! bottom of this file.

use salaad::config::ModelConfig;
use salaad::runtime::Runtime;
use salaad::util::rng::Rng;

/// Fixture token stream mirror of aot.make_fixtures.
fn fixture_tokens(vocab: usize, batch: usize, seq: usize, seed: u64)
                  -> Vec<i32> {
    let mut rng = Rng::named("fixture.tokens", seed);
    (0..batch * seq).map(|_| (rng.next_u64() % vocab as u64) as i32).collect()
}

#[test]
fn from_env_selects_native_without_artifacts() {
    // Selection depends on the artifacts dir under the xla feature, and
    // an explicit SALAAD_BACKEND override invalidates the premise.
    if cfg!(feature = "xla") || std::env::var("SALAAD_BACKEND").is_ok() {
        return;
    }
    let rt = Runtime::from_env().unwrap();
    assert_eq!(rt.backend_name(), "native");
    // Builtin registry carries all four standard scales.
    for scale in ["nano", "micro", "mini", "small"] {
        let cfg = rt.model_config(scale).unwrap();
        assert_eq!(cfg.seq_len, 128);
        assert_eq!(cfg.params.len(), 3 + 9 * cfg.n_layers);
    }
}

#[test]
fn logits_entry_shape_and_stats() {
    let rt = Runtime::native();
    let cfg = rt.model_config("nano").unwrap();
    let params = cfg.init_params(0);
    let toks = fixture_tokens(cfg.vocab, 1, cfg.seq_len, 0);
    let out = rt.forward_logits(&cfg, &params, &toks, 1).unwrap();
    assert_eq!(out.shape, vec![1, cfg.seq_len, cfg.vocab]);
    assert!(out.is_finite());
    // At init (0.02-std weights) logits are small and centered.
    let mean: f64 = out.data.iter().map(|x| *x as f64).sum::<f64>()
        / out.numel() as f64;
    assert!(mean.abs() < 0.1, "init logits mean {mean}");
    // Deterministic.
    let again = rt.forward_logits(&cfg, &params, &toks, 1).unwrap();
    assert_eq!(out, again);
}

#[test]
fn eval_loss_matches_fwd_bwd_loss() {
    let rt = Runtime::native();
    let cfg = rt.model_config("nano").unwrap();
    let params = cfg.init_params(7);
    let toks = fixture_tokens(cfg.vocab, cfg.batch, cfg.seq_len, 7);
    let (sum, count) = rt.eval_loss(&cfg, &params, &toks).unwrap();
    let (loss, grads) = rt.loss_and_grads(&cfg, &params, &toks).unwrap();
    assert_eq!(count as usize, cfg.batch * (cfg.seq_len - 1));
    assert!((sum / count - loss).abs() < 1e-6,
            "eval {} vs fwd_bwd {loss}", sum / count);
    // Loss at init sits near ln(vocab) — the untrained baseline.
    let ln_v = (cfg.vocab as f64).ln();
    assert!((loss - ln_v).abs() < 0.5, "init loss {loss} vs ln V {ln_v}");
    // Gradients: one per parameter, right shapes, finite, not all zero.
    assert_eq!(grads.len(), cfg.params.len());
    for (g, (name, shape)) in grads.iter().zip(&cfg.params) {
        assert_eq!(&g.shape, shape, "grad shape of {name}");
        assert!(g.is_finite(), "grad of {name} not finite");
    }
    let embed_norm = grads[cfg.param_index("embed").unwrap()].frob_norm();
    let head_norm = grads[cfg.param_index("lm_head").unwrap()].frob_norm();
    assert!(embed_norm > 1e-4, "embed grad vanished: {embed_norm}");
    assert!(head_norm > 1e-4, "head grad vanished: {head_norm}");
}

#[test]
fn gradient_direction_reduces_loss() {
    // A small step along −∇ must reduce the loss — a cheap end-to-end
    // check that the hand-written backward pass points downhill.
    let rt = Runtime::native();
    let cfg = ModelConfig::from_geometry("t", 32, 16, 1, 2, 24, 16, 2);
    let params = cfg.init_params(1);
    let toks = fixture_tokens(cfg.vocab, cfg.batch, cfg.seq_len, 1);
    let (loss0, grads) = rt.loss_and_grads(&cfg, &params, &toks).unwrap();
    let gnorm2: f64 = grads.iter().map(|g| g.frob_norm().powi(2)).sum();
    let step = (0.05 / gnorm2.sqrt()) as f32;
    let moved: Vec<_> = params
        .iter()
        .zip(&grads)
        .map(|(p, g)| {
            let mut q = p.clone();
            q.axpy(-step, g);
            q
        })
        .collect();
    let (loss1, _) = rt.loss_and_grads(&cfg, &moved, &toks).unwrap();
    assert!(loss1 < loss0, "step along -grad grew loss: {loss0} -> {loss1}");
}

#[test]
fn per_row_independence_of_forward() {
    // Row b of a 2-row batch must equal the single-row forward of that
    // row: no cross-sequence leakage through attention or norms.
    let rt = Runtime::native();
    let cfg = ModelConfig::from_geometry("t", 32, 16, 1, 2, 24, 12, 2);
    let params = cfg.init_params(4);
    let toks = fixture_tokens(cfg.vocab, 2, cfg.seq_len, 4);
    let both = rt.forward_logits(&cfg, &params, &toks, 2).unwrap();
    for b in 0..2 {
        let row = &toks[b * cfg.seq_len..(b + 1) * cfg.seq_len];
        let one = rt.forward_logits(&cfg, &params, row, 1).unwrap();
        let n = cfg.seq_len * cfg.vocab;
        let got = &both.data[b * n..(b + 1) * n];
        for (x, y) in got.iter().zip(&one.data) {
            assert!((x - y).abs() < 1e-5, "row {b} diverged");
        }
    }
}

#[test]
fn causality_of_logits() {
    // Changing a future token must not change logits at earlier
    // positions (causal mask + next-token loss contract).
    let rt = Runtime::native();
    let cfg = ModelConfig::from_geometry("t", 32, 16, 1, 2, 24, 12, 2);
    let params = cfg.init_params(9);
    let mut toks = fixture_tokens(cfg.vocab, 1, cfg.seq_len, 9);
    let a = rt.forward_logits(&cfg, &params, &toks, 1).unwrap();
    let cut = cfg.seq_len / 2;
    for t in cut..cfg.seq_len {
        toks[t] = (toks[t] + 1) % cfg.vocab as i32;
    }
    let b = rt.forward_logits(&cfg, &params, &toks, 1).unwrap();
    let v = cfg.vocab;
    for t in 0..cut {
        for j in 0..v {
            let (x, y) = (a.data[t * v + j], b.data[t * v + j]);
            assert!((x - y).abs() < 1e-5,
                    "future token leaked into position {t}");
        }
    }
}

// ---------------------------------------------------------------------
// Cross-language PJRT contract tests (require `--features xla` and
// `make artifacts`; skipped silently when artifacts are absent).
#[cfg(feature = "xla")]
mod pjrt {
    use super::fixture_tokens;
    use salaad::runtime::literal::{literal_scalar, tensor_to_literal};
    use salaad::runtime::{Backend, PjrtBackend};
    use salaad::tensor::Tensor;
    use salaad::util::rng::Rng;

    fn backend() -> Option<PjrtBackend> {
        let dir = std::env::var("SALAAD_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string());
        if !std::path::Path::new(&dir).join("manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return None;
        }
        Some(PjrtBackend::new(dir).expect("pjrt backend"))
    }

    #[test]
    fn kernel_soft_threshold_roundtrip() {
        let Some(rt) = backend() else { return };
        let exe = rt.load_kernel("soft_threshold").unwrap();
        let mut rng = Rng::new(0);
        let z = Tensor::randn(&[128, 128], &mut rng, 1.0);
        let tau = Tensor::new(vec![0.5], &[1, 1]);
        let out = exe
            .run_tensors(&[tensor_to_literal(&z).unwrap(),
                           tensor_to_literal(&tau).unwrap()])
            .unwrap();
        let want = salaad::slr::prox::soft_threshold(&z, 0.5);
        assert!(out[0].dist_frob(&want) < 1e-5,
                "pallas soft_threshold != rust prox");
    }

    #[test]
    fn kernel_matmul_roundtrip() {
        let Some(rt) = backend() else { return };
        let exe = rt.load_kernel("matmul").unwrap();
        let mut rng = Rng::new(1);
        let x = Tensor::randn(&[128, 256], &mut rng, 1.0);
        let w = Tensor::randn(&[256, 192], &mut rng, 1.0);
        let out = exe
            .run_tensors(&[tensor_to_literal(&x).unwrap(),
                           tensor_to_literal(&w).unwrap()])
            .unwrap();
        let want = salaad::linalg::matmul(&x, &w);
        let rel = out[0].dist_frob(&want) / (1.0 + want.frob_norm());
        assert!(rel < 1e-5, "pallas matmul mismatch rel={rel}");
    }

    #[test]
    fn kernel_slr_matmul_matches_block_apply() {
        let Some(rt) = backend() else { return };
        let exe = rt.load_kernel("slr_matmul").unwrap();
        let (t, m, n, r) = (128, 192, 160, 32);
        let mut rng = Rng::new(2);
        let x = Tensor::randn(&[t, m], &mut rng, 1.0);
        let u = Tensor::randn(&[n, r], &mut rng, 1.0);
        let s = Tensor::randn(&[r], &mut rng, 1.0);
        let v = Tensor::randn(&[m, r], &mut rng, 1.0);
        let sp = Tensor::randn(&[n, m], &mut rng, 0.05);
        let out = exe
            .run_tensors(&[&x, &u, &s, &v, &sp]
                .iter()
                .map(|t| tensor_to_literal(t).unwrap())
                .collect::<Vec<_>>())
            .unwrap();
        // Dense reference: x @ (U diag(s) V^T + sp)^T
        let mut w = salaad::linalg::reconstruct(&u, &s.data, &v);
        w.add_assign(&sp);
        let want = salaad::linalg::matmul_nt(&x, &w);
        let rel = out[0].dist_frob(&want) / (1.0 + want.frob_norm());
        assert!(rel < 1e-4, "slr_matmul mismatch rel={rel}");
    }

    #[test]
    fn fixtures_loss_parity_nano() {
        let Some(rt) = backend() else { return };
        let fx = rt.fixtures().unwrap();
        let fx = fx.req("nano").unwrap();
        let seed = fx.req("seed").unwrap().as_f64().unwrap() as u64;
        let cfg = rt.model_config("nano").unwrap();

        // Token stream parity first (cheap, catches RNG drift with a
        // clear message).
        let toks = fixture_tokens(cfg.vocab, cfg.batch, cfg.seq_len, seed);
        let first: Vec<f64> = fx
            .req("tokens_first_row").unwrap()
            .as_arr().unwrap()
            .iter()
            .map(|x| x.as_f64().unwrap())
            .collect();
        for (i, want) in first.iter().enumerate() {
            assert_eq!(toks[i] as f64, *want, "token stream drift at {i}");
        }

        // Parameter checksum parity.
        let params = cfg.init_params(seed);
        let embed_sum: f64 = params[0].data.iter().map(|x| *x as f64).sum();
        let want_embed = fx.req("param_checksums").unwrap()
            .req("embed").unwrap().as_f64().unwrap();
        assert!((embed_sum - want_embed).abs()
                    < 1e-2 * (1.0 + want_embed.abs()),
                "embed checksum {embed_sum} vs {want_embed}");

        // Full eval_loss through the HLO executable.
        let exe = rt.load_entry(&cfg, "eval_loss").unwrap();
        let inputs = rt.pack_inputs(&cfg, &params, &toks, cfg.batch)
            .unwrap();
        let out = exe.run(&inputs).unwrap();
        let sum = literal_scalar(&out[0]).unwrap();
        let count = literal_scalar(&out[1]).unwrap();
        let want_count = fx.req("eval_count").unwrap().as_f64().unwrap();
        assert_eq!(count, want_count);
        let loss = sum / count;
        let want = fx.req("loss").unwrap().as_f64().unwrap();
        assert!((loss - want).abs() < 5e-3, "loss {loss} vs jax {want}");
    }

    #[test]
    fn fwd_bwd_grad_norms_match_fixtures() {
        let Some(rt) = backend() else { return };
        let fx = rt.fixtures().unwrap();
        let fx = fx.req("nano").unwrap();
        let seed = fx.req("seed").unwrap().as_f64().unwrap() as u64;
        let cfg = rt.model_config("nano").unwrap();
        let params = cfg.init_params(seed);
        let toks = fixture_tokens(cfg.vocab, cfg.batch, cfg.seq_len, seed);
        let (loss, grads) =
            rt.loss_and_grads(&cfg, &params, &toks).unwrap();
        assert_eq!(grads.len(), cfg.params.len());
        let want_loss = fx.req("loss").unwrap().as_f64().unwrap();
        assert!((loss - want_loss).abs() < 5e-3);
        // Gradient norms for embed (first) and head (last).
        let g_embed = grads[0].frob_norm();
        let want_embed =
            fx.req("grad_norm_embed").unwrap().as_f64().unwrap();
        assert!((g_embed - want_embed).abs() < 5e-3 * (1.0 + want_embed),
                "embed grad norm {g_embed} vs {want_embed}");
        let g_head = grads[grads.len() - 1].frob_norm();
        let want_head = fx.req("grad_norm_head").unwrap().as_f64().unwrap();
        assert!((g_head - want_head).abs() < 5e-3 * (1.0 + want_head),
                "head grad norm {g_head} vs {want_head}");
    }

    #[test]
    fn logits_mean_matches_fixtures() {
        let Some(rt) = backend() else { return };
        let fx = rt.fixtures().unwrap();
        let fx = fx.req("nano").unwrap();
        let seed = fx.req("seed").unwrap().as_f64().unwrap() as u64;
        let cfg = rt.model_config("nano").unwrap();
        let params = cfg.init_params(seed);
        let toks = fixture_tokens(cfg.vocab, cfg.batch, cfg.seq_len, seed);
        let row0: Vec<i32> = toks[..cfg.seq_len].to_vec();
        let out = rt.forward_logits(&cfg, &params, &row0, 1).unwrap();
        assert_eq!(out.shape, vec![1, cfg.seq_len, cfg.vocab]);
        let mean: f64 = out.data.iter().map(|x| *x as f64).sum::<f64>()
            / out.numel() as f64;
        let want = fx.req("logits_mean").unwrap().as_f64().unwrap();
        assert!((mean - want).abs() < 1e-3 * (1.0 + want.abs()),
                "logits mean {mean} vs {want}");
    }

    #[test]
    fn forward_pallas_matches_logits_path() {
        // Dense pallas forward (Layer-1 kernels) vs the jnp-fused logits
        // entrypoint — same params, same tokens, same numbers.
        let Some(rt) = backend() else { return };
        let cfg = rt.model_config("nano").unwrap();
        if !cfg.entrypoints.contains_key("forward_pallas") {
            return;
        }
        let params = cfg.init_params(7);
        let toks = fixture_tokens(cfg.vocab, 1, cfg.seq_len, 99);
        let a = rt.load_entry(&cfg, "logits").unwrap()
            .run_tensors(&rt.pack_inputs(&cfg, &params, &toks, 1).unwrap())
            .unwrap();
        let b = rt.load_entry(&cfg, "forward_pallas").unwrap()
            .run_tensors(&rt.pack_inputs(&cfg, &params, &toks, 1).unwrap())
            .unwrap();
        let rel = a[0].dist_frob(&b[0]) / (1.0 + a[0].frob_norm());
        assert!(rel < 1e-4, "pallas vs jnp forward rel={rel}");
    }

    #[test]
    fn native_matches_pjrt_eval_loss() {
        // The two backends implement the same model: same params, same
        // tokens, same numbers (within f32 re-association tolerance).
        let Some(rt) = backend() else { return };
        let cfg = rt.model_config("nano").unwrap();
        let params = cfg.init_params(0);
        let toks = fixture_tokens(cfg.vocab, cfg.batch, cfg.seq_len, 0);
        let (sum_p, count_p) = rt.eval_loss(&cfg, &params, &toks).unwrap();
        let native = salaad::runtime::NativeBackend::new();
        let (sum_n, count_n) =
            native.eval_loss(&cfg, &params, &toks).unwrap();
        assert_eq!(count_p, count_n);
        assert!((sum_p / count_p - sum_n / count_n).abs() < 5e-3,
                "pjrt {} vs native {}", sum_p / count_p, sum_n / count_n);
    }
}
