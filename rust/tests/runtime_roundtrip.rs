//! Integration tests for the PJRT runtime against real AOT artifacts.
//!
//! These verify the entire cross-language contract: Rust-initialized
//! parameters (SplitMix64 mirror) fed into python-lowered HLO reproduce
//! the loss/gradient numbers recorded in artifacts/fixtures.json by JAX.
//!
//! Requires `make artifacts` to have run (skipped otherwise).

use salaad::runtime::literal::{literal_scalar, tensor_to_literal};
use salaad::runtime::Runtime;
use salaad::tensor::Tensor;
use salaad::util::rng::Rng;

fn runtime() -> Option<Runtime> {
    let dir = std::env::var("SALAAD_ARTIFACTS")
        .unwrap_or_else(|_| "artifacts".to_string());
    if !std::path::Path::new(&dir).join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(Runtime::new(dir).expect("runtime"))
}

/// Fixture token stream mirror of aot.make_fixtures.
fn fixture_tokens(vocab: usize, batch: usize, seq: usize, seed: u64)
                  -> Vec<i32> {
    let mut rng = Rng::named("fixture.tokens", seed);
    (0..batch * seq).map(|_| (rng.next_u64() % vocab as u64) as i32).collect()
}

#[test]
fn kernel_soft_threshold_roundtrip() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_kernel("soft_threshold").unwrap();
    let mut rng = Rng::new(0);
    let z = Tensor::randn(&[128, 128], &mut rng, 1.0);
    let tau = Tensor::new(vec![0.5], &[1, 1]);
    let out = exe
        .run_tensors(&[tensor_to_literal(&z).unwrap(),
                       tensor_to_literal(&tau).unwrap()])
        .unwrap();
    assert_eq!(out.len(), 1);
    let want = salaad::slr::prox::soft_threshold(&z, 0.5);
    assert!(out[0].dist_frob(&want) < 1e-5,
            "pallas soft_threshold != rust prox");
}

#[test]
fn kernel_matmul_roundtrip() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_kernel("matmul").unwrap();
    let mut rng = Rng::new(1);
    let x = Tensor::randn(&[128, 256], &mut rng, 1.0);
    let w = Tensor::randn(&[256, 192], &mut rng, 1.0);
    let out = exe
        .run_tensors(&[tensor_to_literal(&x).unwrap(),
                       tensor_to_literal(&w).unwrap()])
        .unwrap();
    let want = salaad::linalg::matmul(&x, &w);
    let rel = out[0].dist_frob(&want) / (1.0 + want.frob_norm());
    assert!(rel < 1e-5, "pallas matmul mismatch rel={rel}");
}

#[test]
fn kernel_slr_matmul_matches_block_apply() {
    let Some(rt) = runtime() else { return };
    let exe = rt.load_kernel("slr_matmul").unwrap();
    let (t, m, n, r) = (128, 192, 160, 32);
    let mut rng = Rng::new(2);
    let x = Tensor::randn(&[t, m], &mut rng, 1.0);
    let u = Tensor::randn(&[n, r], &mut rng, 1.0);
    let s = Tensor::randn(&[r], &mut rng, 1.0);
    let v = Tensor::randn(&[m, r], &mut rng, 1.0);
    let sp = Tensor::randn(&[n, m], &mut rng, 0.05);
    let out = exe
        .run_tensors(&[&x, &u, &s, &v, &sp]
            .iter()
            .map(|t| tensor_to_literal(t).unwrap())
            .collect::<Vec<_>>())
        .unwrap();
    // Dense reference: x @ (U diag(s) V^T + sp)^T
    let mut w = salaad::linalg::reconstruct(&u, &s.data, &v);
    w.add_assign(&sp);
    let want = salaad::linalg::matmul_nt(&x, &w);
    let rel = out[0].dist_frob(&want) / (1.0 + want.frob_norm());
    assert!(rel < 1e-4, "slr_matmul mismatch rel={rel}");
}

#[test]
fn fixtures_loss_parity_nano() {
    let Some(rt) = runtime() else { return };
    let fx = rt.fixtures().unwrap();
    let fx = fx.req("nano").unwrap();
    let seed = fx.req("seed").unwrap().as_f64().unwrap() as u64;
    let cfg = rt.model_config("nano").unwrap();

    // Token stream parity first (cheap, catches RNG drift with a clear
    // message).
    let toks = fixture_tokens(cfg.vocab, cfg.batch, cfg.seq_len, seed);
    let first: Vec<f64> = fx
        .req("tokens_first_row").unwrap()
        .as_arr().unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap())
        .collect();
    for (i, want) in first.iter().enumerate() {
        assert_eq!(toks[i] as f64, *want, "token stream drift at {i}");
    }

    // Parameter checksum parity.
    let params = cfg.init_params(seed);
    let embed_sum: f64 = params[0].data.iter().map(|x| *x as f64).sum();
    let want_embed = fx.req("param_checksums").unwrap()
        .req("embed").unwrap().as_f64().unwrap();
    assert!((embed_sum - want_embed).abs() < 1e-2 * (1.0 + want_embed.abs()),
            "embed checksum {embed_sum} vs {want_embed}");

    // Full eval_loss through the HLO executable.
    let exe = rt.load_entry(&cfg, "eval_loss").unwrap();
    let inputs = rt.pack_inputs(&cfg, &params, &toks, cfg.batch).unwrap();
    let out = exe.run(&inputs).unwrap();
    let sum = literal_scalar(&out[0]).unwrap();
    let count = literal_scalar(&out[1]).unwrap();
    let want_sum = fx.req("eval_sum").unwrap().as_f64().unwrap();
    let want_count = fx.req("eval_count").unwrap().as_f64().unwrap();
    assert_eq!(count, want_count);
    let loss = sum / count;
    let want_loss = fx.req("loss").unwrap().as_f64().unwrap();
    assert!((loss - want_loss).abs() < 5e-3,
            "loss {loss} vs jax {want_loss}");
}

#[test]
fn fwd_bwd_grad_norms_match_fixtures() {
    let Some(rt) = runtime() else { return };
    let fx = rt.fixtures().unwrap();
    let fx = fx.req("nano").unwrap();
    let seed = fx.req("seed").unwrap().as_f64().unwrap() as u64;
    let cfg = rt.model_config("nano").unwrap();
    let params = cfg.init_params(seed);
    let toks = fixture_tokens(cfg.vocab, cfg.batch, cfg.seq_len, seed);
    let exe = rt.load_entry(&cfg, "fwd_bwd").unwrap();
    let inputs = rt.pack_inputs(&cfg, &params, &toks, cfg.batch).unwrap();
    let out = exe.run_tensors(&inputs).unwrap();
    assert_eq!(out.len(), 1 + cfg.params.len());
    let loss = out[0].data[0] as f64;
    let want_loss = fx.req("loss").unwrap().as_f64().unwrap();
    assert!((loss - want_loss).abs() < 5e-3);
    // Gradient norms for embed (index 1) and head (last).
    let g_embed = out[1].frob_norm();
    let want_embed = fx.req("grad_norm_embed").unwrap().as_f64().unwrap();
    assert!((g_embed - want_embed).abs() < 5e-3 * (1.0 + want_embed),
            "embed grad norm {g_embed} vs {want_embed}");
    let g_head = out[out.len() - 1].frob_norm();
    let want_head = fx.req("grad_norm_head").unwrap().as_f64().unwrap();
    assert!((g_head - want_head).abs() < 5e-3 * (1.0 + want_head),
            "head grad norm {g_head} vs {want_head}");
}

#[test]
fn logits_entry_shape_and_stats() {
    let Some(rt) = runtime() else { return };
    let fx = rt.fixtures().unwrap();
    let fx = fx.req("nano").unwrap();
    let seed = fx.req("seed").unwrap().as_f64().unwrap() as u64;
    let cfg = rt.model_config("nano").unwrap();
    let params = cfg.init_params(seed);
    let toks = fixture_tokens(cfg.vocab, cfg.batch, cfg.seq_len, seed);
    let row0: Vec<i32> = toks[..cfg.seq_len].to_vec();
    let exe = rt.load_entry(&cfg, "logits").unwrap();
    let inputs = rt.pack_inputs(&cfg, &params, &row0, 1).unwrap();
    let out = exe.run_tensors(&inputs).unwrap();
    assert_eq!(out[0].shape, vec![1, cfg.seq_len, cfg.vocab]);
    let mean: f64 = out[0].data.iter().map(|x| *x as f64).sum::<f64>()
        / out[0].numel() as f64;
    let want_mean = fx.req("logits_mean").unwrap().as_f64().unwrap();
    assert!((mean - want_mean).abs() < 1e-3 * (1.0 + want_mean.abs()),
            "logits mean {mean} vs {want_mean}");
}

#[test]
fn forward_pallas_matches_logits_path() {
    // Dense pallas forward (Layer-1 kernels) vs the jnp-fused logits
    // entrypoint — same params, same tokens, same numbers.
    let Some(rt) = runtime() else { return };
    let cfg = rt.model_config("nano").unwrap();
    if !cfg.entrypoints.contains_key("forward_pallas") {
        return;
    }
    let params = cfg.init_params(7);
    let toks = fixture_tokens(cfg.vocab, 1, cfg.seq_len, 99);
    let a = rt.load_entry(&cfg, "logits").unwrap()
        .run_tensors(&rt.pack_inputs(&cfg, &params, &toks, 1).unwrap())
        .unwrap();
    let b = rt.load_entry(&cfg, "forward_pallas").unwrap()
        .run_tensors(&rt.pack_inputs(&cfg, &params, &toks, 1).unwrap())
        .unwrap();
    let rel = a[0].dist_frob(&b[0]) / (1.0 + a[0].frob_norm());
    assert!(rel < 1e-4, "pallas vs jnp forward rel={rel}");
}
