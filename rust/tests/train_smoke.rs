//! End-to-end coordinator integration: train the nano model for a small
//! number of steps through the runtime (the native backend by default —
//! no artifacts required, so CI exercises the real train/compress/serve
//! loop on every run) and check that (a) the loss decreases, (b)
//! SALAAD's surrogate develops SLR structure tracking the dense
//! weights, (c) HPA produces a working compressed model, and (d)
//! checkpoints round-trip.

use salaad::config::{SalaadConfig, TrainConfig};
use salaad::coordinator::{checkpoint, Method, Trainer};
use salaad::data::BatchLoader;
use salaad::eval::eval_ppl;
use salaad::runtime::Runtime;
use salaad::slr::hpa;

fn runtime() -> Runtime {
    // Prefer the environment's backend choice, but never skip: these
    // smoke tests must run (on the native backend) even when an xla
    // override is present without the feature compiled in.
    Runtime::from_env().unwrap_or_else(|_| Runtime::native())
}

fn quick_tcfg(steps: usize) -> TrainConfig {
    TrainConfig { steps, lr: 2e-3, warmup_steps: 5, eval_every: 0,
                  log_every: 1000, eval_batches: 2, seed: 11,
                  ..Default::default() }
}

fn quick_scfg() -> SalaadConfig {
    SalaadConfig { k_steps: 5, admm_workers: 4, rho_const: 2.0,
                   ..Default::default() }
}

#[test]
fn salaad_training_reduces_loss_and_builds_structure() {
    let rt = runtime();
    let cfg = rt.model_config("nano").unwrap();
    let mut tr = Trainer::new(&rt, cfg.clone(), Method::Salaad,
                              quick_tcfg(40), quick_scfg()).unwrap();
    tr.run().unwrap();

    // (a) loss decreased materially from ~ln(vocab).
    let first = tr.history.losses[0];
    let last = tr.history.trailing_loss(5).unwrap();
    assert!(last < first - 0.5,
            "loss did not decrease: {first} -> {last}");

    // (b) surrogate structure exists and tracks X.
    assert!(!tr.history.phases.is_empty());
    let p = tr.history.phases.last().unwrap();
    assert!(p.avg_recon.is_finite() && p.avg_recon > 0.0);
    let any_rank = tr.blocks.iter().any(|b| b.rank() > 0);
    assert!(any_rank, "no block developed low-rank structure");

    // Surrogate model evaluates to a finite, sane PPL.
    let eval_set = BatchLoader::eval_set(cfg.vocab, cfg.batch, cfg.seq_len,
                                         11, 2);
    let ppl_x = eval_ppl(&rt, &cfg, &tr.params, &eval_set).unwrap();
    let ppl_sur = eval_ppl(&rt, &cfg, &tr.surrogate_params(), &eval_set)
        .unwrap();
    assert!(ppl_x.is_finite() && ppl_x < cfg.vocab as f64);
    assert!(ppl_sur.is_finite() && ppl_sur < cfg.vocab as f64 * 2.0,
            "surrogate ppl {ppl_sur}");

    // (c) HPA at a 30% removal budget still evaluates finitely and
    // strictly reduces the parameter count.
    let pool = hpa::plan(&tr.blocks, 0.7, 0).unwrap();
    let budget = (pool.c_l + pool.c_s) * 3 / 10;
    let plan = hpa::plan(&tr.blocks, 0.7, budget).unwrap();
    let (trunc, report) = hpa::apply(&tr.blocks, &plan);
    assert!(report.params_after < report.params_before);
    let ppl_hpa = eval_ppl(&rt, &cfg, &tr.params_with_blocks(&trunc),
                           &eval_set).unwrap();
    assert!(ppl_hpa.is_finite(), "hpa ppl {ppl_hpa}");

    // (d) checkpoint round-trip preserves params and blocks.
    let dir = std::env::temp_dir().join(format!(
        "salaad_smoke_ckpt_{}", std::process::id()));
    let named: Vec<(String, salaad::tensor::Tensor)> = cfg
        .params
        .iter()
        .map(|(n, _)| n.clone())
        .zip(tr.params.iter().cloned())
        .collect();
    checkpoint::save_checkpoint(&dir, &cfg.name, "salaad", tr.step, &named,
                                &tr.blocks, salaad::util::Json::obj())
        .unwrap();
    let ck = checkpoint::load_checkpoint(&dir).unwrap();
    assert_eq!(ck.params.len(), tr.params.len());
    assert_eq!(ck.blocks.len(), tr.blocks.len());
    let restored: Vec<salaad::tensor::Tensor> =
        ck.params.into_iter().map(|(_, t)| t).collect();
    let ppl_restored = eval_ppl(&rt, &cfg, &restored, &eval_set).unwrap();
    assert!((ppl_restored - ppl_x).abs() < 1e-6);
    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn fullrank_baseline_trains() {
    let rt = runtime();
    let cfg = rt.model_config("nano").unwrap();
    let mut tr = Trainer::new(&rt, cfg, Method::FullRank, quick_tcfg(15),
                              quick_scfg()).unwrap();
    tr.run().unwrap();
    assert!(tr.blocks.is_empty());
    let first = tr.history.losses[0];
    let last = tr.history.trailing_loss(3).unwrap();
    assert!(last < first, "full-rank loss did not decrease");
}

#[test]
fn penalty_keeps_training_stable() {
    // §4.2's claim: the inductive term does not destabilize the base
    // optimizer. Train SALAAD and full-rank with identical seeds: loss
    // trajectories should stay close early in training.
    let rt = runtime();
    let cfg = rt.model_config("nano").unwrap();
    let mut a = Trainer::new(&rt, cfg.clone(), Method::Salaad,
                             quick_tcfg(20), quick_scfg()).unwrap();
    a.run().unwrap();
    let mut b = Trainer::new(&rt, cfg, Method::FullRank, quick_tcfg(20),
                             quick_scfg()).unwrap();
    b.run().unwrap();
    let la = a.history.trailing_loss(5).unwrap();
    let lb = b.history.trailing_loss(5).unwrap();
    assert!((la - lb).abs() < 0.35,
            "penalty destabilized training: salaad {la} vs dense {lb}");
}

#[test]
fn serve_smoke() {
    use salaad::serve::{Request, Server, ServerOptions};
    use std::time::Duration;
    let rt = runtime();
    let cfg = rt.model_config("nano").unwrap();
    let mut tr = Trainer::new(&rt, cfg.clone(), Method::Salaad,
                              quick_tcfg(12), quick_scfg()).unwrap();
    tr.run().unwrap();

    let mut server = Server::new(
        &rt, cfg.clone(), &tr.params, &tr.blocks, &tr.block_param_idx,
        &[0.3, 0.6],
        ServerOptions { max_batch: 4, max_wait: Duration::from_millis(5),
                        ..ServerOptions::default() }).unwrap();
    // Variants are param-count sorted, deduplicated, strictly
    // ascending; at most full + one per requested budget.
    assert!(!server.variants.is_empty() && server.variants.len() <= 3);
    for w in server.variants.windows(2) {
        assert!(w[0].params_count < w[1].params_count);
    }
    // On factored-capable backends the variants are zero-copy views
    // over shared master stores: the byte split is populated and the
    // whole spectrum's marginal cost stays a sliver of the shared
    // weights, even for a briefly-trained (weakly compressed)
    // surrogate. Backends without factored execution memoize dense
    // copies per variant, so the bound does not apply there.
    if rt.supports_incremental() {
        assert!(server.stats.shared_bytes > 0);
        assert!(server.stats.marginal_bytes > 0);
        assert!(server.stats.marginal_bytes * 10
                    < server.stats.shared_bytes,
                "spectrum marginal {}B not below 10% of shared {}B",
                server.stats.marginal_bytes, server.stats.shared_bytes);
        for v in &server.variants {
            assert!(v.n_factored() > 0,
                    "variant {} holds no factored views",
                    v.params_count);
        }
    }

    let (req_tx, req_rx) = std::sync::mpsc::channel();
    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
    let producer = std::thread::spawn(move || {
        for i in 0..6u64 {
            let budget = if i % 2 == 0 { 0 } else { 1 };
            req_tx
                .send(Request::new(i, vec![3, 1, 4, 1, 5], 3, budget))
                .unwrap();
        }
        // Dropping req_tx closes the channel; server run() returns.
    });
    server.run(req_rx, resp_tx).unwrap();
    producer.join().unwrap();
    let responses: Vec<_> = resp_rx.iter().collect();
    assert_eq!(responses.len(), 6);
    for r in &responses {
        assert_eq!(r.tokens.len(), 3);
        assert!(r.tokens.iter().all(|t| (*t as usize) < cfg.vocab));
        assert!(r.latency_ms > 0.0);
        assert!(r.queue_ms >= 0.0);
    }
    // A 1-param budget is below every variant: the smallest serves it
    // and the response is flagged over-budget.
    let small = server.variants[0].params_count;
    for r in responses.iter().filter(|r| r.id % 2 == 1) {
        assert_eq!(r.served_params, small);
        assert!(r.over_budget, "over-budget fallback not flagged");
    }
    for r in responses.iter().filter(|r| r.id % 2 == 0) {
        assert!(!r.over_budget);
    }
}
