//! Closed-loop elasticity, end to end: deterministic burst/recovery
//! traces through the continuous scheduler with the autoscaler armed.
//!
//! The burst test pins the whole control loop in one run: a long
//! request admitted at the full budget before the controller can
//! shift, a queue of short followers that forces a downshift, their
//! admission onto the controller-carved budget, an upshift once the
//! queue drains (while the long request still decodes), mid-run
//! garbage collection of the carve — and, throughout, the elasticity
//! contract: zero drops, in-flight rows never migrate, and every
//! response is token-identical to a solo run at its recorded
//! `served_at_frac`.
//!
//! Wall-clock signals (the windowed queue-wait threshold) are
//! disabled so the trace is driven by queue depth and occupancy
//! alone — fully deterministic on any machine.

use std::sync::mpsc::channel;
use std::time::Duration;

use salaad::config::ModelConfig;
use salaad::runtime::Runtime;
use salaad::serve::{AutoscaleConfig, ControlEffect, ControlPlane,
                    Request, Response, Server, ServerOptions};
use salaad::slr::SlrBlock;

fn tiny_cfg() -> ModelConfig {
    ModelConfig::from_geometry("tiny", 32, 8, 1, 2, 16, 24, 2)
}

/// Synthetic developed blocks over the attention projections so a
/// Server can be built without running training (the idiom of the
/// in-crate server tests).
fn tiny_server(rt: &Runtime) -> Server<'_> {
    let cfg = tiny_cfg();
    let params = cfg.init_params(0);
    let mut blocks = Vec::new();
    let mut idx = Vec::new();
    for name in cfg.blocks(true, false) {
        let shape = cfg.shape_of(&name).unwrap().to_vec();
        blocks.push(SlrBlock::random(&name, shape[0], shape[1], 3,
                                     0.1, 0));
        idx.push(cfg.param_index(&name).unwrap());
    }
    // Full-only spectrum: every capacity point below the surrogate is
    // the controller's to carve (and to garbage-collect).
    Server::new(rt, cfg, &params, &blocks, &idx, &[],
                ServerOptions {
                    max_batch: 2,
                    max_wait: Duration::from_millis(2),
                    kappa: 0.7,
                    block_tokens: 4,
                })
        .unwrap()
}

/// Queue-depth-driven config: hot at depth ≥ 2, wait signal disabled,
/// calm while only the long row's ≤0.5 occupancy remains.
fn depth_driven_cfg() -> AutoscaleConfig {
    AutoscaleConfig {
        ladder: vec![0.5],
        high_queue_depth: 2,
        high_occupancy: 0.95,
        high_queue_wait_ms: 1e9,
        low_occupancy: 0.6,
        down_window: 2,
        up_window: 2,
        cooldown: 2,
    }
}

#[test]
fn burst_downshifts_recovers_and_stays_token_identical() {
    let rt = Runtime::native();
    let mut server = tiny_server(&rt);
    assert_eq!(server.variants.len(), 1, "full-only spectrum");
    let full_pc = server.variants[0].params_count;
    match server
        .apply(ControlPlane::EnableAutoscale {
            cfg: depth_driven_cfg() })
        .unwrap()
    {
        ControlEffect::AutoscaleEnabled { levels } => {
            assert_eq!(levels, 1);
        }
        _ => panic!("EnableAutoscale must report itself"),
    }

    // All pre-queued: no sleeps, fully deterministic. With 2 slots
    // and down_window 2, r0 (long) and r1 admit at the full budget on
    // the first poll; the queued followers keep depth ≥ 2 for two
    // polls, forcing a downshift before any of them is admitted.
    let sched: [(u64, Vec<u32>, usize); 5] = [(0, vec![1, 2, 3], 20),
                                              (1, vec![4, 5, 6], 2),
                                              (2, vec![2, 3], 2),
                                              (3, vec![5, 1, 2], 2),
                                              (4, vec![3, 4], 2)];
    let (req_tx, req_rx) = channel();
    let (resp_tx, resp_rx) = channel();
    for (id, prompt, max_new) in &sched {
        req_tx.send(Request::new(*id, prompt.clone(), *max_new, 0))
            .unwrap();
    }
    drop(req_tx);
    server.run(req_rx, resp_tx).unwrap();
    let mut got: Vec<Response> = resp_rx.iter().collect();
    got.sort_by_key(|r| r.id);
    assert_eq!(got.len(), 5, "every request must round-trip");

    // The control-loop trace: one downshift under the burst, one
    // upshift in the idle tail (r0 still decoding alone at ≤0.5
    // occupancy), the carve garbage-collected mid-run once its last
    // rider retired, level 0 at drain.
    let s = &server.stats;
    assert_eq!(s.autoscale_downshifts, 1,
               "the queued followers must force exactly one downshift");
    assert_eq!(s.autoscale_upshifts, 1,
               "the idle tail must recover the controller");
    assert_eq!(s.autoscale_final_level, 0);
    assert_eq!(s.autoscale_deepest_level, 1);
    assert_eq!(s.autoscale_retired, 1,
               "the carve must be GC'd while r0 still decodes");
    assert_eq!(s.dropped_responses, 0);

    // Admission routing: the first wave rode the full surrogate (the
    // controller had not shifted yet); every follower was throttled
    // onto the 0.5 carve. Throttling never sets over_budget — it is
    // a serving decision, not a client error.
    assert_eq!(got[0].served_params, full_pc);
    assert_eq!(got[0].served_at_frac, 0.0);
    assert_eq!(got[1].served_params, full_pc);
    for r in &got[2..] {
        assert_eq!(r.served_at_frac, 0.5,
                   "follower {} must ride the throttled budget", r.id);
        assert_ne!(r.served_params, full_pc);
    }
    assert!(got.iter().all(|r| !r.over_budget));
    // The GC really removed the carve: only the full surrogate
    // survives the run.
    assert_eq!(server.variants.len(), 1,
               "the controller must clean up after itself");

    // The replay contract: even though the 0.5 carve is gone,
    // re-admitting each recorded fraction rebuilds identical cuts
    // (HPA planning is deterministic) and a solo decode reproduces
    // every response's tokens bit-exactly.
    for r in &got {
        let vi = server.admit_budget(r.served_at_frac).unwrap();
        let (_, prompt, max_new) = &sched[r.id as usize];
        let p = server.prepare_prompt(prompt, *max_new);
        let solo = server
            .generate_cached(&server.variants[vi], &[p], &[*max_new])
            .unwrap();
        assert_eq!(r.tokens, solo[0],
                   "request {} at frac {} diverged from its solo run",
                   r.id, r.served_at_frac);
    }
}

#[test]
fn idle_autoscaler_is_invisible_to_scheduling() {
    // A controller that never crosses a threshold must be a pure
    // observer: same variants, same routing, same tokens as an
    // unarmed server over the identical schedule.
    let rt = Runtime::native();
    let mut plain = tiny_server(&rt);
    let mut armed = tiny_server(&rt);
    armed
        .apply(ControlPlane::EnableAutoscale {
            cfg: AutoscaleConfig {
                high_queue_depth: usize::MAX,
                high_queue_wait_ms: f64::INFINITY,
                ..AutoscaleConfig::default()
            },
        })
        .unwrap();
    let serve = |server: &mut Server<'_>| -> Vec<Response> {
        let (req_tx, req_rx) = channel();
        let (resp_tx, resp_rx) = channel();
        for id in 0..4u64 {
            let prompt = vec![1 + id as u32, 2, 3];
            req_tx.send(Request::new(id, prompt, 3, 0)).unwrap();
        }
        drop(req_tx);
        server.run(req_rx, resp_tx).unwrap();
        let mut got: Vec<Response> = resp_rx.iter().collect();
        got.sort_by_key(|r| r.id);
        got
    };
    let want = serve(&mut plain);
    let got = serve(&mut armed);
    assert_eq!(got.len(), want.len());
    for (g, w) in got.iter().zip(&want) {
        assert_eq!(g.tokens, w.tokens,
                   "an idle controller changed request {}'s tokens",
                   g.id);
        assert_eq!(g.served_params, w.served_params);
        assert_eq!(g.served_at_frac, w.served_at_frac);
    }
    assert_eq!(armed.stats.autoscale_downshifts, 0);
    assert_eq!(armed.stats.autoscale_upshifts, 0);
    assert_eq!(armed.stats.autoscale_final_level, 0);
    assert_eq!(armed.variants.len(), plain.variants.len());
}
