//! Benchmark harness (`cargo bench`, custom harness — criterion is not
//! in the offline vendor set; DESIGN.md §3).
//!
//! Covers the hot paths of each layer plus one end-to-end bench per
//! paper-table driver:
//!   L3 numeric core : jacobi/randomized SVD (the ε in Appendix C's
//!                     ε·J/K cost model), prox ops, ADMM block update,
//!                     HPA, RPCA, GEMMs, data loader
//!   gemm            : tiled/microkernel GEMM variants vs an in-bench
//!                     naive ikj reference (the pre-tiling algorithm),
//!                     so one run shows the kernel speedup ratio
//!   backend         : fwd_bwd/eval/logits step latency per scale
//!                     (table1/fig2/fig3 drivers) through the active
//!                     Runtime backend (native by default)
//!   serving         : logits latency dense vs factored (U,s,V,CSR-S),
//!                     full-prompt prefill per scale (the fused
//!                     streaming-softmax attention path), and greedy
//!                     decode with vs without the KV cache
//!
//! Set SALAAD_BENCH_FILTER=<substr>[|<substr>…] to run a subset; each
//! '|'-separated alternative is matched as a substring (e.g.
//! `SALAAD_BENCH_FILTER='serve|gemm|prefill'` — the CI bench job's
//! filter).

use std::time::Instant;

use salaad::config::{SalaadConfig, TrainConfig};
use salaad::coordinator::{run_admm_phase, Method, Trainer};
use salaad::data::BatchLoader;
use salaad::linalg::{jacobi_svd, matmul, matmul_nt, matmul_tn, rand_svd};
use salaad::runtime::{ModelParams, PackedPrompts, Runtime};
use salaad::serve::{AutoscaleConfig, ControlPlane, Request, Server,
                    ServerOptions};
use salaad::slr::prox::{soft_threshold_assign, svt};
use salaad::slr::{hpa, rpca::rpca, BcsrMatrix, CsrMatrix, SlrBlock};
use salaad::tensor::Tensor;
use salaad::util::Rng;

struct Bench {
    filter: Option<String>,
    results: Vec<(String, f64, f64, u32)>,
}

impl Bench {
    fn new() -> Self {
        Bench {
            filter: std::env::var("SALAAD_BENCH_FILTER").ok(),
            results: Vec::new(),
        }
    }

    /// Run `f` repeatedly: warmup, then timed iterations adapting the
    /// count so each bench takes ~0.4-1s. Records median + mean.
    fn bench(&mut self, name: &str, mut f: impl FnMut()) {
        if let Some(filt) = &self.filter {
            // '|'-separated alternatives, each a substring match.
            if !filt.split('|').any(|alt| name.contains(alt)) {
                return;
            }
        }
        // Warmup + calibration.
        let t0 = Instant::now();
        f();
        let once = t0.elapsed().as_secs_f64();
        let iters = ((0.5 / once.max(1e-9)) as u32).clamp(3, 200);
        let mut samples = Vec::with_capacity(iters as usize);
        for _ in 0..iters {
            let t = Instant::now();
            f();
            samples.push(t.elapsed().as_secs_f64());
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<f64>() / samples.len() as f64;
        println!("{name:<44} median {:>10.3} ms   mean {:>10.3} ms   \
                  ({iters} iters)", median * 1e3, mean * 1e3);
        self.results.push((name.to_string(), median, mean, iters));
    }

    /// Write `reports/bench.md` (human table) and `reports/bench.json`
    /// (machine-readable: name → {median_ms, mean_ms, iters} — what
    /// the CI bench-regression job uploads as `BENCH_PR4.json` and
    /// diffs against `ci/bench_baseline.json`).
    fn report(&self) {
        let mut out = String::from("| bench | median ms | mean ms | iters |\n\
                                    |---|---|---|---|\n");
        for (n, med, mean, it) in &self.results {
            out.push_str(&format!("| {n} | {:.3} | {:.3} | {it} |\n",
                                  med * 1e3, mean * 1e3));
        }
        let _ = std::fs::create_dir_all("reports");
        let _ = std::fs::write("reports/bench.md", out);
        let mut j = salaad::util::Json::obj();
        for (n, med, mean, it) in &self.results {
            let mut e = salaad::util::Json::obj();
            e.set("median_ms", salaad::util::Json::Num(med * 1e3));
            e.set("mean_ms", salaad::util::Json::Num(mean * 1e3));
            e.set("iters", salaad::util::Json::Num(*it as f64));
            j.set(n, e);
        }
        let _ = j.write_file(std::path::Path::new("reports/bench.json"));
    }
}

fn main() {
    let mut b = Bench::new();
    let mut rng = Rng::new(0);

    // ---------------- L3 numeric core ----------------
    for (n, m) in [(128usize, 128usize), (256, 128), (512, 128)] {
        let a = Tensor::randn(&[n, m], &mut rng, 1.0);
        b.bench(&format!("linalg/jacobi_svd_{n}x{m}"), || {
            std::hint::black_box(jacobi_svd(&a));
        });
        let mut r2 = Rng::new(1);
        b.bench(&format!("linalg/rand_svd_r32_{n}x{m}"), || {
            std::hint::black_box(rand_svd(&a, 32, 8, 2, &mut r2));
        });
    }
    {
        let a = Tensor::randn(&[256, 256], &mut rng, 1.0);
        let c = Tensor::randn(&[256, 256], &mut rng, 1.0);
        b.bench("linalg/matmul_256", || {
            std::hint::black_box(matmul(&a, &c));
        });
        b.bench("linalg/matmul_nt_256", || {
            std::hint::black_box(matmul_nt(&a, &c));
        });
    }

    // ---------------- GEMM microbenches ----------------
    // Tiled kernels vs the naive single-thread ikj reference (the
    // pre-tiling inner loop, zero-skip included) — one run yields the
    // before/after kernel ratio recorded in EXPERIMENTS.md §GEMM.
    fn naive_ikj(a: &Tensor, c: &Tensor) -> Tensor {
        let (n, k) = (a.nrows(), a.ncols());
        let m = c.ncols();
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..n {
            let row = &mut out.data[i * m..(i + 1) * m];
            for l in 0..k {
                let av = a.data[i * k + l];
                if av == 0.0 {
                    continue;
                }
                for (o, bv) in
                    row.iter_mut().zip(&c.data[l * m..(l + 1) * m])
                {
                    *o += av * *bv;
                }
            }
        }
        out
    }
    for size in [128usize, 256, 512] {
        let a = Tensor::randn(&[size, size], &mut rng, 1.0);
        let c = Tensor::randn(&[size, size], &mut rng, 1.0);
        b.bench(&format!("gemm/naive_ikj_{size}"), || {
            std::hint::black_box(naive_ikj(&a, &c));
        });
        b.bench(&format!("gemm/matmul_{size}"), || {
            std::hint::black_box(matmul(&a, &c));
        });
        b.bench(&format!("gemm/matmul_nt_{size}"), || {
            std::hint::black_box(matmul_nt(&a, &c));
        });
        b.bench(&format!("gemm/matmul_tn_{size}"), || {
            std::hint::black_box(matmul_tn(&a, &c));
        });
    }
    {
        // The serving shapes that dominate prefill: activations × a
        // d×d projection, and activations × the lm_head.
        let x = Tensor::randn(&[128, 192], &mut rng, 1.0);
        let w = Tensor::randn(&[192, 192], &mut rng, 0.1);
        let head = Tensor::randn(&[1024, 192], &mut rng, 0.1);
        b.bench("gemm/proj_nt_128x192x192", || {
            std::hint::black_box(matmul_nt(&x, &w));
        });
        b.bench("gemm/lmhead_nt_128x192x1024", || {
            std::hint::black_box(matmul_nt(&x, &head));
        });
    }

    // ---------------- sparse-residual kernels ----------------
    // CSR gather vs the 8-wide panel (BCSR) layout over the same
    // residual, at a low and a mid density, plus the rank-masked
    // mid-spectrum cut (the elastic-serving hot path). Before/after
    // numbers recorded in EXPERIMENTS.md §Sparse-residual kernels.
    for dpct in [10usize, 60] {
        let d = dpct as f64 / 100.0;
        let mut nz = 0usize;
        let mut s = Tensor::zeros(&[256, 256]);
        for v in s.data.iter_mut() {
            if rng.next_f64() < d {
                *v = (rng.next_normal() as f32).max(0.05);
                nz += 1;
            }
        }
        let csr = CsrMatrix::from_dense(&s, 0.0);
        assert_eq!(csr.nnz(), nz);
        let mut ranks: Vec<u32> = (0..nz as u32).collect();
        rng.shuffle(&mut ranks);
        let bcsr = BcsrMatrix::from_csr(&csr, &ranks);
        let x = Tensor::randn(&[64, 256], &mut rng, 1.0);
        b.bench(&format!("slr/spmm_csr_256_d{dpct}"), || {
            std::hint::black_box(csr.spmm_t(&x));
        });
        b.bench(&format!("slr/spmm_bcsr_256_d{dpct}"), || {
            std::hint::black_box(bcsr.spmm_t(&x));
        });
        b.bench(&format!("slr/spmm_bcsr_cut50_256_d{dpct}"), || {
            std::hint::black_box(bcsr.spmm_t_cut(&x, nz / 2));
        });
    }
    {
        let z = Tensor::randn(&[512, 512], &mut rng, 1.0);
        b.bench("prox/soft_threshold_512", || {
            let mut zz = z.clone();
            soft_threshold_assign(&mut zz, 0.3);
            std::hint::black_box(zz);
        });
        let mut r2 = Rng::new(2);
        b.bench("prox/svt_tau0.5_r32_512", || {
            std::hint::black_box(svt(&z, 0.5, 32, &mut r2));
        });
    }
    {
        // ADMM phase over a micro-like block set (the fig2 inner loop).
        let sizes = [(512usize, 128usize), (128, 128), (128, 128),
                     (128, 128), (128, 128), (352, 128), (352, 128),
                     (128, 352)];
        let blocks: Vec<SlrBlock> = sizes
            .iter()
            .enumerate()
            .map(|(i, (n, m))| {
                let mut blk = SlrBlock::new(&format!("b{i}"), *n, *m,
                                            0.01, 0.5, 0.5);
                blk.alpha = 0.005;
                blk.beta = 0.0005;
                blk
            })
            .collect();
        let xs: Vec<Tensor> = sizes
            .iter()
            .map(|(n, m)| Tensor::randn(&[*n, *m], &mut rng, 0.1))
            .collect();
        let caps: Vec<usize> = sizes.iter().map(|(n, m)| n.min(m) / 2)
            .collect();
        for workers in [1usize, 4] {
            let mut bl = blocks.clone();
            b.bench(&format!("admm/phase_8blocks_w{workers}"), || {
                let mut blc = bl.clone();
                std::hint::black_box(run_admm_phase(
                    &mut blc, &xs, &caps, workers, 1, 0.999, 0));
                bl = blc;
            });
        }
        // HPA on developed blocks (the fig3/fig4 inner loop).
        let mut developed = blocks.clone();
        for (blk, x) in developed.iter_mut().zip(&xs) {
            let mut r3 = Rng::new(3);
            salaad::slr::admm::admm_update(blk, x, 1, 64, 0.999, &mut r3);
        }
        b.bench("hpa/plan_apply_30pct", || {
            let pool = hpa::plan(&developed, 0.7, 0).unwrap();
            let plan = hpa::plan(&developed, 0.7,
                                 (pool.c_l + pool.c_s) / 3).unwrap();
            std::hint::black_box(hpa::apply(&developed, &plan));
        });
    }
    {
        let w = Tensor::randn(&[128, 128], &mut rng, 0.1);
        let mut r2 = Rng::new(4);
        b.bench("rpca/inexact_alm_128", || {
            std::hint::black_box(rpca(&w, 1.0, 30, 1e-5, &mut r2));
        });
    }
    {
        let mut loader = BatchLoader::new(512, 8, 128, "bench", 0);
        b.bench("data/batch_8x128", || {
            std::hint::black_box(loader.next_batch());
        });
    }

    // ---------------- backend + end-to-end ----------------
    {
        let rt = Runtime::from_env().expect("runtime");
        eprintln!("backend: {}", rt.describe());
        for scale in ["nano", "micro", "mini"] {
            let cfg = rt.model_config(scale).unwrap();
            let params = cfg.init_params(0);
            let mut loader = BatchLoader::new(cfg.vocab, cfg.batch,
                                              cfg.seq_len, "bench", 0);
            let batch = loader.next_batch();
            // fwd_bwd step (table1/fig2 driver hot path).
            b.bench(&format!("e2e/fwd_bwd_step_{scale}"), || {
                std::hint::black_box(
                    rt.loss_and_grads(&cfg, &params, &batch).unwrap());
            });
            // eval_loss (fig3/fig4/table ppl driver).
            b.bench(&format!("e2e/eval_loss_{scale}"), || {
                std::hint::black_box(
                    rt.eval_loss(&cfg, &params, &batch).unwrap());
            });
            // serving logits latency (1×T).
            let one: Vec<i32> = batch[..cfg.seq_len].to_vec();
            b.bench(&format!("serve/logits_1x{}_{scale}", cfg.seq_len),
                    || {
                std::hint::black_box(
                    rt.forward_logits(&cfg, &params, &one, 1).unwrap());
            });
            // Full-prompt prefill (fused streaming-softmax attention +
            // KV-cache build) — the serving-side cost of admitting a
            // request. Before/after numbers for the fused-attention
            // PR are recorded in EXPERIMENTS.md §Prefill.
            if rt.supports_incremental() {
                let mp = ModelParams::from_dense(&params);
                let full = PackedPrompts::equal(&one, 1).unwrap();
                b.bench(&format!("serve/prefill_1x{}_{scale}",
                                 cfg.seq_len), || {
                    std::hint::black_box(
                        rt.prefill(&cfg, &mp, &full).unwrap());
                });
                let half = PackedPrompts::equal(
                    &one[..cfg.seq_len / 2], 1).unwrap();
                b.bench(&format!("serve/prefill_1x{}_{scale}",
                                 cfg.seq_len / 2), || {
                    std::hint::black_box(
                        rt.prefill(&cfg, &mp, &half).unwrap());
                });
                // Ragged packing: one left-padded rows=4 prefill over
                // mixed prompt lengths vs the 4 solo prefills the
                // per-length grouping used to run (nano only — the
                // ratio, not the scale, is the point).
                if scale == "nano" {
                    let t = cfg.seq_len;
                    let mixed: Vec<Vec<i32>> =
                        [t / 8, t / 4, t / 2, t - 1]
                            .into_iter()
                            .map(|l| (0..l)
                                .map(|i| ((i * 13 + 3) % cfg.vocab)
                                    as i32)
                                .collect())
                            .collect();
                    let pack = PackedPrompts::pack(&mixed).unwrap();
                    b.bench("serve/prefill_ragged_pack4_nano", || {
                        std::hint::black_box(
                            rt.prefill(&cfg, &mp, &pack).unwrap());
                    });
                    let solos: Vec<PackedPrompts> = mixed.iter()
                        .map(|p| PackedPrompts::equal(p, 1).unwrap())
                        .collect();
                    b.bench("serve/prefill_solo4_nano", || {
                        for s in &solos {
                            std::hint::black_box(
                                rt.prefill(&cfg, &mp, s).unwrap());
                        }
                    });
                }
            }
        }

        // Factored serving path: dense-vs-factored logits and
        // cached-vs-uncached greedy decode (the ROADMAP "factored
        // serving" + "KV-cached incremental decoding" items; numbers
        // recorded in EXPERIMENTS.md §Serving).
        for scale in ["nano", "micro"] {
            let cfg = rt.model_config(scale).unwrap();
            let t = cfg.seq_len;
            let mut blocks = Vec::new();
            let mut idx = Vec::new();
            for name in cfg.blocks(true, true) {
                let shape = cfg.shape_of(&name).unwrap().to_vec();
                blocks.push(SlrBlock::random(&name, shape[0], shape[1],
                                             8, 0.05, 0));
                idx.push(cfg.param_index(&name).unwrap());
            }
            let params = cfg.init_params(0);
            let mut server = Server::new(&rt, cfg.clone(), &params,
                                         &blocks, &idx, &[0.5],
                                         ServerOptions::default())
                .unwrap();
            let variant = server.variants.first().unwrap();
            eprintln!("{scale} compressed variant: resident {} B vs \
                       dense {} B ({} factored blocks)",
                      variant.resident_bytes(), variant.dense_bytes(),
                      variant.n_factored());
            let factored_one: Vec<i32> =
                (0..t).map(|i| ((i * 31 + 5) % cfg.vocab) as i32)
                    .collect();
            b.bench(&format!("serve/logits_factored_1x{t}_{scale}"), || {
                std::hint::black_box(
                    rt.forward_logits_model(&cfg, &variant.params,
                                            &factored_one, 1)
                        .unwrap());
            });
            let prompt =
                server.prepare_prompt(&[5, 4, 3, 2, 1, 0, 1, 2], 32);
            b.bench(&format!("serve/decode32_uncached_{scale}"), || {
                std::hint::black_box(
                    server.generate_uncached(variant, &prompt, 32)
                        .unwrap());
            });
            b.bench(&format!("serve/decode32_cached_{scale}"), || {
                std::hint::black_box(
                    server.generate_cached(variant,
                                           &[prompt.clone()], &[32])
                        .unwrap());
            });
            // Per-token decode cost must not grow with the total
            // sequence length: emit per-position step times at two
            // context depths for the O(T) claim.
            for max_new in [8usize, 64] {
                b.bench(&format!(
                    "serve/decode{max_new}_cached_{scale}"), || {
                    std::hint::black_box(
                        server.generate_cached(variant,
                                               &[prompt.clone()],
                                               &[max_new])
                            .unwrap());
                });
            }
            // Runtime elasticity: carving a fresh budget on a live
            // server (HPA plan over master shapes + O(blocks) view
            // construction, no weight copies) then retiring it. The
            // fraction cycles so each iteration admits a genuinely
            // new capacity point rather than hitting the dedup path.
            let mut step = 0u64;
            b.bench(&format!("serve/admit_budget_{scale}"), || {
                step += 1;
                let frac = 0.05 + 0.85 * ((step % 97) as f64 / 97.0);
                let before = server.variants.len();
                let vi = server.admit_budget(frac).unwrap();
                if server.variants.len() > before {
                    server.retire(vi).unwrap();
                }
                std::hint::black_box(server.variants.len());
            });
            // Continuous scheduling under burst: 12 pre-queued
            // requests with staggered prompt/generation lengths over 8
            // decode slots, so late requests enter mid-decode as short
            // rows retire (the serve-smoke schedule; numbers recorded
            // in EXPERIMENTS.md §Tail latency under continuous
            // batching).
            if scale == "nano" && rt.supports_incremental() {
                b.bench("serve/continuous_burst_nano", || {
                    let (req_tx, req_rx) = std::sync::mpsc::channel();
                    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
                    for i in 0..12u64 {
                        let plen = 4 + (i as usize * 5) % 23;
                        let max_new = 2 + (i as usize * 7) % 15;
                        let prompt: Vec<u32> = (0..plen)
                            .map(|j| ((j * 13 + 3) % cfg.vocab) as u32)
                            .collect();
                        req_tx.send(Request::new(i, prompt, max_new, 0))
                            .unwrap();
                    }
                    drop(req_tx);
                    server.run(req_rx, resp_tx).unwrap();
                    std::hint::black_box(resp_rx.iter().count());
                });
                // The same burst with the closed-loop controller in
                // the scheduler: the delta over continuous_burst_nano
                // is the price of windowed telemetry polls plus any
                // mid-run carve/retire the trace triggers. Armed
                // fresh each iteration so every run replays the same
                // level-0 start.
                let keep: Vec<usize> = server.variants.iter()
                    .map(|v| v.params_count)
                    .collect();
                b.bench("serve/continuous_burst_autoscale_nano", || {
                    server
                        .apply(ControlPlane::EnableAutoscale {
                            cfg: AutoscaleConfig::default(),
                        })
                        .unwrap();
                    let (req_tx, req_rx) = std::sync::mpsc::channel();
                    let (resp_tx, resp_rx) = std::sync::mpsc::channel();
                    for i in 0..12u64 {
                        let plen = 4 + (i as usize * 5) % 23;
                        let max_new = 2 + (i as usize * 7) % 15;
                        let prompt: Vec<u32> = (0..plen)
                            .map(|j| ((j * 13 + 3) % cfg.vocab) as u32)
                            .collect();
                        req_tx.send(Request::new(i, prompt, max_new, 0))
                            .unwrap();
                    }
                    drop(req_tx);
                    server.run(req_rx, resp_tx).unwrap();
                    std::hint::black_box(resp_rx.iter().count());
                    server.apply(ControlPlane::DisableAutoscale)
                        .unwrap();
                });
                // A run that ends mid-throttle leaves its carve
                // admitted; drop it so the speculate benches below
                // see the original spectrum (and its smallest point).
                while let Some(i) = server.variants.iter()
                    .position(|v| !keep.contains(&v.params_count))
                {
                    server.retire(i).unwrap();
                }
            }
            // Self-speculative decode at 64 tokens: the default
            // drafter (smallest admitted budget's cuts — a zero-copy
            // view over the same master stores) proposes k tokens per
            // round, the full variant verifies. Hold against
            // serve/decode64_cached_nano, the non-speculative 64-token
            // baseline of the same prompt — the decode-speedup
            // protocol in EXPERIMENTS.md §Self-speculative decoding.
            if scale == "nano" && rt.supports_incremental() {
                let drafter = server.carve_drafter(None).unwrap();
                let master = server.variants.last().unwrap();
                for k in [4usize, 8] {
                    b.bench(&format!("serve/speculate_k{k}_nano"), || {
                        std::hint::black_box(
                            server.generate_speculative(
                                master, &drafter, &prompt, 64, k)
                                .unwrap());
                    });
                }
                let spec = server
                    .generate_speculative(master, &drafter, &prompt,
                                          64, 4)
                    .unwrap();
                eprintln!("nano speculate k=4: {} drafted, {} \
                           accepted ({:.0}%), {} rounds for {} tokens",
                          spec.counters.drafted, spec.counters.accepted,
                          spec.counters.acceptance_rate() * 100.0,
                          spec.counters.rounds, spec.tokens.len());
            }
        }

        // One short SALAAD training step sequence (fully end-to-end).
        let cfg = rt.model_config("nano").unwrap();
        let tcfg = TrainConfig { steps: 1, eval_every: 0,
                                 ..Default::default() };
        let scfg = SalaadConfig { k_steps: 1, ..Default::default() };
        let mut tr = Trainer::new(&rt, cfg, Method::Salaad, tcfg, scfg)
            .unwrap();
        tr.grad_step().unwrap(); // warm caches
        b.bench("e2e/salaad_grad_plus_admm_nano", || {
            tr.grad_step().unwrap();
            tr.admm_phase().unwrap();
        });
    }

    b.report();
    println!("\nwrote reports/bench.md");
}
