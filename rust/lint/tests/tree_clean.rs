//! The whole-tree gate: `cargo test -p salaad-lint` fails if any
//! contract rule fires on `rust/src` — the same scan CI runs via
//! `cargo run -p salaad-lint`, so the contracts are enforced even for
//! contributors who only run the test suite.

use std::path::PathBuf;

#[test]
fn repo_tree_is_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("lint crate lives under rust/")
        .join("src");
    assert!(root.is_dir(), "missing source root {}", root.display());
    let (files, findings) = salaad_lint::walk::lint_root(&root);
    assert!(files > 30, "suspiciously few files scanned: {files}");
    let rendered: Vec<String> =
        findings.iter().map(|f| f.render()).collect();
    assert!(
        findings.is_empty(),
        "salaad-lint found {} contract violation(s) in {} files:\n{}",
        findings.len(),
        files,
        rendered.join("\n")
    );
}

#[test]
fn self_check_fixtures_pass() {
    let errs = salaad_lint::fixtures::self_check();
    assert!(errs.is_empty(), "{}", errs.join("\n"));
}
