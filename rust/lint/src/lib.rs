//! `salaad-lint` — repo-specific static contract checks.
//!
//! The SALAAD tree's headline guarantee (one training run, a
//! bit-identical capacity spectrum at every budget, served without
//! falling over) rests on contracts no general-purpose tool checks:
//! the normative `dot8`/`axpy8` accumulation order, a panic-free
//! serve path, a single sanctioned `unsafe` site, lock-free decode
//! scheduling, and rustdoc as the API contract. This crate enforces
//! them as five lexical rules over a masked view of the source — see
//! [`rules`] for the rules, [`source`] for the masking lexer, and
//! [`allow`] for the `// salaad-lint: allow(<rule>, reason = "...")`
//! suppression protocol.
//!
//! Deliberately dependency-free (the build environment has no crate
//! registry access, so `syn` is not an option) and deliberately
//! textual: the rules trade full parse fidelity for zero build cost
//! and total predictability, and every heuristic is pinned by the
//! fixtures in [`fixtures`], which both `cargo test` and the CLI's
//! `--self-check` mode replay.

pub mod allow;
pub mod fixtures;
pub mod rules;
pub mod source;
pub mod walk;
