//! CLI for `salaad-lint`: `cargo run -p salaad-lint -- [--self-check]
//! [paths…]`.
//!
//! With no paths, lints `rust/src` (the workspace-root invocation CI
//! uses). Prints `path:line: [rule] message` per finding and exits
//! non-zero if anything fires — including malformed allow-markers, so
//! a reason-less suppression can never merge. `--self-check` replays
//! the fixture suite instead, proving the lexer and rules still catch
//! what they claim to before the tree scan is trusted.

use std::path::Path;
use std::process::ExitCode;

fn main() -> ExitCode {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.iter().any(|a| a == "--self-check") {
        let errs = salaad_lint::fixtures::self_check();
        return if errs.is_empty() {
            println!(
                "salaad-lint --self-check: {} fixtures ok",
                salaad_lint::fixtures::FIXTURES.len()
            );
            ExitCode::SUCCESS
        } else {
            for e in &errs {
                eprintln!("self-check FAILED: {e}");
            }
            ExitCode::FAILURE
        };
    }
    let roots: Vec<String> = if args.is_empty() {
        vec!["rust/src".to_string()]
    } else {
        args
    };
    let mut findings = Vec::new();
    let mut files = 0usize;
    for root in &roots {
        let (n, fs) = salaad_lint::walk::lint_root(Path::new(root));
        files += n;
        findings.extend(fs);
    }
    findings.sort();
    for f in &findings {
        println!("{}", f.render());
    }
    if findings.is_empty() {
        println!("salaad-lint: {files} files clean");
        ExitCode::SUCCESS
    } else {
        eprintln!("salaad-lint: {} finding(s) in {files} files",
                  findings.len());
        ExitCode::FAILURE
    }
}
