//! Filesystem walk + top-level lint driver shared by the CLI and the
//! whole-tree integration test.

use crate::rules::{analyze, Finding};
use std::path::{Path, PathBuf};

/// Collect every `*.rs` file under `root` (or `root` itself if it is
/// a file), sorted for deterministic output.
pub fn rs_files(root: &Path) -> Vec<PathBuf> {
    let mut out = Vec::new();
    if root.is_dir() {
        collect(root, &mut out);
    } else {
        out.push(root.to_path_buf());
    }
    out.sort();
    out
}

fn collect(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    for e in entries.flatten() {
        let p = e.path();
        if p.is_dir() {
            collect(&p, out);
        } else if p.extension().is_some_and(|x| x == "rs") {
            out.push(p);
        }
    }
}

/// Lint every `*.rs` file under `root`. Returns `(files_scanned,
/// findings)`; unreadable files produce a finding rather than an
/// abort, so CI can never skip a file silently.
pub fn lint_root(root: &Path) -> (usize, Vec<Finding>) {
    let mut findings = Vec::new();
    let files = rs_files(root);
    let n = files.len();
    for f in &files {
        let rel = f
            .strip_prefix(root)
            .map(|p| p.to_string_lossy().replace('\\', "/"))
            .unwrap_or_else(|_| f.to_string_lossy().into_owned());
        let display = f.to_string_lossy().into_owned();
        match std::fs::read_to_string(f) {
            Ok(src) => findings.extend(analyze(&rel, &display, &src)),
            Err(e) => findings.push(Finding {
                path: display,
                line: 1,
                rule: "allow-marker",
                msg: format!("unreadable source file: {e}"),
            }),
        }
    }
    findings.sort();
    (n, findings)
}
