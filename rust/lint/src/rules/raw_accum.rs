//! Rule `raw-accum`: no raw f32 accumulation outside `linalg/`.
//!
//! The bit-identical capacity spectrum only holds because every f32
//! reduction on the inference path goes through the normative
//! `linalg::dot8` / `axpy8` / `axpy8x4` kernels, whose accumulation
//! order is pinned by golden tests. A plain `acc += a[i] * b[i]` loop
//! in a new kernel silently re-orders the sum and breaks bit-exactness
//! between budgets. This rule flags, in non-test code under `runtime/`,
//! `serve/`, `slr/` and `tensor/`:
//!
//! - a `+=` statement inside a `for`/`while`/`loop` body whose RHS
//!   contains a binary `*` (a multiply-accumulate), unless the
//!   statement widens with `as f64` (f64 accumulation is outside the
//!   f32 contract — training-loss statistics do this deliberately);
//! - a bare `acc += x` where both sides are single identifiers
//!   (optionally `*`-dereferenced) — the classic running-sum shape;
//! - `.sum::<f32>(` anywhere (iterator reduction with unpinned order);
//! - `.fold(0.0` with a `+` later on the line (an additive fold; the
//!   order-safe `fold(f32::NEG_INFINITY, f32::max)` shape is fine).
//!
//! Integer counters (`self.stats.groups += 1`) and indexed
//! non-multiply updates don't match either shape and pass untouched.
//! Genuine normative kernels and training-path scatter-adds carry
//! `// salaad-lint: allow(raw-accum, reason = "...")`.

use super::{find_all, in_dirs, Finding};
use crate::source::Analysis;

const SCOPE: &[&str] = &["runtime/", "serve/", "slr/", "tensor/"];
const RULE: &str = "raw-accum";

/// Run the rule over one file.
pub fn run(rel: &str, path: &str, an: &Analysis) -> Vec<Finding> {
    let mut out = Vec::new();
    if !in_dirs(rel, SCOPE) {
        return out;
    }
    let s = &an.masked;
    for i in find_all(s, "+=") {
        if an.is_test[i] || an.loop_depth[i] == 0 {
            continue;
        }
        let start = stmt_start(s, i);
        let end = match s[i..].find(';') {
            Some(p) => i + p,
            None => (i + 400).min(s.len()),
        };
        let stmt = &s[start..end];
        if stmt.contains("as f64") {
            continue;
        }
        let lhs = s[start..i].trim();
        let rhs = s[i + 2..end].trim();
        let flagged = has_binary_star(rhs)
            || (is_bare_operand(lhs) && is_bare_operand(rhs));
        if flagged {
            out.push(Finding {
                path: path.to_string(),
                line: an.line_of(i),
                rule: RULE,
                msg: "raw f32 accumulation in a loop outside linalg/ — \
                      route through linalg::dot8/axpy8, widen with `as \
                      f64`, or add `// salaad-lint: allow(raw-accum, \
                      reason = \"...\")`"
                    .to_string(),
            });
        }
    }
    for i in find_all(s, ".sum::<f32>") {
        if an.is_test[i] {
            continue;
        }
        out.push(Finding {
            path: path.to_string(),
            line: an.line_of(i),
            rule: RULE,
            msg: ".sum::<f32>() has no pinned accumulation order — \
                  route through linalg::dot8 or add an allow marker"
                .to_string(),
        });
    }
    for i in find_all(s, ".fold(0.0") {
        if an.is_test[i] {
            continue;
        }
        let (_, le) = an.line_span(i);
        if s[i..le].contains('+') {
            out.push(Finding {
                path: path.to_string(),
                line: an.line_of(i),
                rule: RULE,
                msg: "additive fold from 0.0 has no pinned accumulation \
                      order — route through linalg kernels or add an \
                      allow marker"
                    .to_string(),
            });
        }
    }
    out
}

/// Byte offset of the start of the statement containing `i`: one past
/// the previous `;`, `{` or `}`.
fn stmt_start(s: &str, i: usize) -> usize {
    let b = s.as_bytes();
    let mut j = i;
    while j > 0 {
        let c = b[j - 1];
        if c == b';' || c == b'{' || c == b'}' {
            return j;
        }
        j -= 1;
    }
    0
}

/// Does `rhs` contain a `*` used as a binary operator (its previous
/// non-whitespace char ends a value: identifier, `]`, `)`, or a
/// literal)?
fn has_binary_star(rhs: &str) -> bool {
    let b = rhs.as_bytes();
    for (k, &c) in b.iter().enumerate() {
        if c != b'*' {
            continue;
        }
        let mut j = k;
        while j > 0 && (b[j - 1] == b' ' || b[j - 1] == b'\n') {
            j -= 1;
        }
        if j == 0 {
            continue; // leading deref
        }
        let p = b[j - 1];
        if p.is_ascii_alphanumeric() || p == b'_' || p == b']'
            || p == b')' || p == b'"'
        {
            return true;
        }
    }
    false
}

/// Is `t` a single identifier, optionally behind `*` derefs — the
/// shape of a running-sum accumulator?
fn is_bare_operand(t: &str) -> bool {
    let t = t.trim_start_matches('*').trim();
    !t.is_empty()
        && t.bytes().all(|c| c.is_ascii_alphanumeric() || c == b'_')
        && !t.as_bytes()[0].is_ascii_digit()
}
