//! Rule `unsafe-scope`: `unsafe` only in the explicit whitelist.
//!
//! The workspace denies `unsafe_code` (`[workspace.lints]`), and the
//! sanctioned escape hatches are the byte-cast in
//! `runtime/literal.rs` and the AVX2 intrinsics module in
//! `linalg/simd.rs` — each documents its safety argument inline and
//! opts out with `#[allow(unsafe_code)]`. This rule is the redundant
//! textual check: any `unsafe` token outside the whitelist is
//! flagged even if a future edit also weakens the compiler-level
//! deny. Extending the whitelist is a reviewed change to WHITELIST
//! here plus the inline safety doc at the new site.

use super::{find_all, Finding};
use crate::source::Analysis;

/// Files (relative to the scan root) allowed to contain `unsafe`.
pub const WHITELIST: &[&str] = &["runtime/literal.rs", "linalg/simd.rs"];

const RULE: &str = "unsafe-scope";

/// Run the rule over one file.
pub fn run(rel: &str, path: &str, an: &Analysis) -> Vec<Finding> {
    let mut out = Vec::new();
    if WHITELIST.contains(&rel) {
        return out;
    }
    let s = &an.masked;
    let b = s.as_bytes();
    for i in find_all(s, "unsafe") {
        if an.is_test[i] {
            continue;
        }
        let pre_ok = i == 0
            || !(b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_');
        let end = i + "unsafe".len();
        let post_ok = end >= b.len()
            || !(b[end].is_ascii_alphanumeric() || b[end] == b'_');
        if pre_ok && post_ok {
            out.push(Finding {
                path: path.to_string(),
                line: an.line_of(i),
                rule: RULE,
                msg: "`unsafe` outside the whitelist \
                      (runtime/literal.rs, linalg/simd.rs) — see \
                      ARCHITECTURE.md §Normative contracts"
                    .to_string(),
            });
        }
    }
    out
}
