//! Rule `doc-gate`: every `pub` item in `slr/`, `serve/`, `runtime/`
//! and `linalg/` carries a doc comment, and every file in those trees
//! opens with `//!` module docs.
//!
//! The rustdoc on those modules is the normative API contract
//! (ARCHITECTURE.md links into it); before this rule the guarantee
//! was a patchwork of per-module `#![warn(missing_docs)]` islands.
//! This gate extends it tree-wide without waiting for a compile:
//!
//! - `pub fn` / `struct` / `enum` / `trait` / `type` / `const` /
//!   `static` / `union` (incl. `pub async fn`, `pub unsafe fn`) and
//!   `pub` struct fields need a `///` (or `#[doc…]`) directly above,
//!   with attribute lines, blank lines and plain comments skipped on
//!   the way up;
//! - `pub use` / `pub mod` re-exports and `pub(crate)` /
//!   `pub(super)` restricted items are exempt (matching rustc's
//!   `missing_docs` scope);
//! - the first non-blank line of the file must start with `//!`.
//!
//! The textual pass is slightly stricter than rustc (it also flags
//! `pub` members of private types); documenting those anyway costs
//! one line and keeps the rule stateless.

use super::{in_dirs, Finding};
use crate::source::Analysis;

const SCOPE: &[&str] = &["slr/", "serve/", "runtime/", "linalg/"];
const RULE: &str = "doc-gate";

const ITEM_KEYWORDS: &[&str] = &[
    "fn", "struct", "enum", "trait", "type", "const", "static",
    "union", "unsafe", "async",
];

/// Run the rule over one file.
pub fn run(rel: &str, path: &str, an: &Analysis) -> Vec<Finding> {
    let mut out = Vec::new();
    if !in_dirs(rel, SCOPE) {
        return out;
    }
    if let Some(first) = an.raw_lines.iter().find(|l| !l.trim().is_empty())
    {
        if !first.trim_start().starts_with("//!") {
            out.push(Finding {
                path: path.to_string(),
                line: 1,
                rule: RULE,
                msg: "file must open with `//!` module docs".to_string(),
            });
        }
    }
    for (l, start) in an.line_start.iter().copied().enumerate() {
        if an.is_test.get(start).copied().unwrap_or(false) {
            continue;
        }
        let end = if l + 1 < an.line_start.len() {
            an.line_start[l + 1] - 1
        } else {
            an.masked.len()
        };
        let line = an.masked[start..end.min(an.masked.len())].trim_start();
        let Some(rest) = line.strip_prefix("pub") else { continue };
        let rest = match rest.strip_prefix(' ') {
            Some(r) => r.trim_start(),
            None => continue, // `pub(crate)`, `publish`, …
        };
        let word: String = rest
            .chars()
            .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
            .collect();
        let is_item = ITEM_KEYWORDS.contains(&word.as_str());
        let is_field = !is_item
            && !word.is_empty()
            && !matches!(word.as_str(), "use" | "mod" | "extern")
            && rest[word.len()..].trim_start().starts_with(':');
        if !is_item && !is_field {
            continue;
        }
        if !has_doc_above(&an.raw_lines, l) {
            out.push(Finding {
                path: path.to_string(),
                line: l + 1,
                rule: RULE,
                msg: format!(
                    "undocumented pub {} — the rustdoc here is the \
                     normative API contract; add a /// line",
                    if is_field { "field" } else { word.as_str() }
                ),
            });
        }
    }
    out
}

/// Walk upward from the line above `l`, skipping attributes, blank
/// lines and plain comments, accepting a doc comment.
fn has_doc_above(raw: &[String], l: usize) -> bool {
    let mut j = l;
    while j > 0 {
        j -= 1;
        let t = raw[j].trim_start();
        if t.starts_with("///") || t.starts_with("#[doc") {
            return true;
        }
        if t.is_empty() || t.starts_with("#[") || t.starts_with("#![")
            || t.starts_with("//")
        {
            continue;
        }
        return false;
    }
    false
}
