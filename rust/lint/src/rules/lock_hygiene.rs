//! Rule `lock-hygiene`: no `Mutex` guard held across a backend call,
//! and no `Mutex::new(&mut …)` smuggling.
//!
//! Two shapes, both learned the hard way in the scheduler loop:
//!
//! 1. **Guard across a backend call** — a function that takes
//!    `.lock()` *and* calls into `Backend::prefill_into` /
//!    `decode_rows` / `forward_*` / `loss_and_grads` serializes every
//!    worker behind one guard (or deadlocks if the backend re-enters).
//!    The paged-KV decode loop must stay lock-free; shared state is
//!    passed by value or split per worker.
//! 2. **`Mutex::new(&mut out)`** — wrapping a `&mut` in a `Mutex` to
//!    satisfy the borrow checker across scoped threads. The cure is
//!    per-slot channels or `split_at_mut` (see `util/parallel.rs`).
//!
//! The check is per-`fn`: any `.lock(` whose innermost enclosing
//! function body also contains a backend-call token fires. Locking in
//! helpers that do no backend work (e.g. the RoPE table cache) passes.

use super::{find_all, Finding};
use crate::source::Analysis;

/// Tokens that mark a backend call on the scheduler/decode path.
pub const BACKEND_TOKENS: &[&str] = &[
    "prefill_into",
    "decode_rows",
    ".prefill(",
    ".decode_step(",
    "forward_logits",
    "forward_model",
    "forward_resolved",
    "loss_and_grads",
    "eval_loss",
];

const RULE: &str = "lock-hygiene";

/// Run the rule over one file.
pub fn run(_rel: &str, path: &str, an: &Analysis) -> Vec<Finding> {
    let mut out = Vec::new();
    let s = &an.masked;
    let b = s.as_bytes();
    for i in find_all(s, "Mutex::new") {
        if an.is_test[i] {
            continue;
        }
        let mut j = i + "Mutex::new".len();
        j = skip_ws(b, j);
        if j < b.len() && b[j] == b'(' {
            j = skip_ws(b, j + 1);
            if j < b.len() && b[j] == b'&' {
                j = skip_ws(b, j + 1);
                if s[j..].starts_with("mut") {
                    out.push(Finding {
                        path: path.to_string(),
                        line: an.line_of(i),
                        rule: RULE,
                        msg: "Mutex::new(&mut …) — use per-slot \
                              channels or split_at_mut instead of \
                              wrapping a unique borrow in a lock"
                            .to_string(),
                    });
                }
            }
        }
    }
    for i in find_all(s, ".lock(") {
        if an.is_test[i] {
            continue;
        }
        let Some((o, c)) = an.enclosing_fn(i) else { continue };
        let body = &s[o..c];
        if let Some(tok) =
            BACKEND_TOKENS.iter().find(|t| body.contains(*t))
        {
            out.push(Finding {
                path: path.to_string(),
                line: an.line_of(i),
                rule: RULE,
                msg: format!(
                    ".lock() in a function that calls the backend \
                     ({tok}) — a guard held across a backend call \
                     serializes the decode loop; restructure or add \
                     an allow marker"
                ),
            });
        }
    }
    out
}

fn skip_ws(b: &[u8], mut j: usize) -> usize {
    while j < b.len() && (b[j] == b' ' || b[j] == b'\n') {
        j += 1;
    }
    j
}
