//! Rule `no-panic-serve`: no panicking calls in non-test code under
//! `serve/` and `runtime/`.
//!
//! A long-running server must degrade, not die: a client hanging up, a
//! malformed request, or a poisoned lock on the decode path has to
//! become a counted [`ServeStats`] error or a `Result`, never an
//! `unwrap()`. Flags `.unwrap()`, `.expect(...)`, `panic!`, `todo!`
//! and `unimplemented!` in non-`#[cfg(test)]` code. `unwrap_or` /
//! `unwrap_or_else` / `unwrap_or_default` are graceful and exempt.
//! Documented programmer-error invariants carry an allow marker with
//! the reason; dynamic invariants belong in `debug_invariant!` (free
//! in release builds) instead.
//!
//! [`ServeStats`]: ../../salaad/serve/struct.ServeStats.html

use super::{find_all, in_dirs, Finding};
use crate::source::Analysis;

const SCOPE: &[&str] = &["serve/", "runtime/"];
const RULE: &str = "no-panic-serve";

/// Run the rule over one file.
pub fn run(rel: &str, path: &str, an: &Analysis) -> Vec<Finding> {
    let mut out = Vec::new();
    if !in_dirs(rel, SCOPE) {
        return out;
    }
    let s = &an.masked;
    let b = s.as_bytes();
    for i in find_all(s, ".unwrap") {
        if an.is_test[i] {
            continue;
        }
        // `.unwrap` then `()` — not `.unwrap_or*`.
        let mut j = i + ".unwrap".len();
        j = skip_ws(b, j);
        if j < b.len() && b[j] == b'(' {
            let k = skip_ws(b, j + 1);
            if k < b.len() && b[k] == b')' {
                out.push(finding(path, an.line_of(i),
                                 ".unwrap() on the serve/runtime path"));
            }
        }
    }
    for i in find_all(s, ".expect") {
        if an.is_test[i] {
            continue;
        }
        let j = skip_ws(b, i + ".expect".len());
        if j < b.len() && b[j] == b'(' {
            out.push(finding(path, an.line_of(i),
                             ".expect(...) on the serve/runtime path"));
        }
    }
    for word in ["panic", "todo", "unimplemented"] {
        for i in word_bangs(s, word) {
            if an.is_test[i] {
                continue;
            }
            out.push(finding(path, an.line_of(i),
                             "panicking macro on the serve/runtime \
                              path"));
        }
    }
    out
}

fn finding(path: &str, line: usize, what: &str) -> Finding {
    Finding {
        path: path.to_string(),
        line,
        rule: RULE,
        msg: format!(
            "{what} — return a Result, count it in ServeStats, use \
             debug_invariant!, or add `// salaad-lint: \
             allow(no-panic-serve, reason = \"...\")`"
        ),
    }
}

fn skip_ws(b: &[u8], mut j: usize) -> usize {
    while j < b.len() && (b[j] == b' ' || b[j] == b'\n') {
        j += 1;
    }
    j
}

/// Offsets of `word` occurrences that are word-bounded on the left and
/// followed (after optional whitespace) by `!`.
fn word_bangs(s: &str, word: &str) -> Vec<usize> {
    let b = s.as_bytes();
    let mut out = Vec::new();
    for i in find_all(s, word) {
        let pre_ok = i == 0
            || !(b[i - 1].is_ascii_alphanumeric() || b[i - 1] == b'_');
        let j = skip_ws(b, i + word.len());
        if pre_ok && j < b.len() && b[j] == b'!' {
            out.push(i);
        }
    }
    out
}
