//! The five repo contracts, as lexical rules over [`Analysis`] views.
//!
//! Each rule module exposes `run(rel, path, an) -> Vec<Finding>`;
//! [`analyze`] wires them together with the allow-marker table from
//! [`crate::allow`]. `rel` is the path relative to the scan root
//! (`rust/src`), used for scoping; `path` is the display path printed
//! in diagnostics.

pub mod doc_gate;
pub mod lock_hygiene;
pub mod no_panic_serve;
pub mod raw_accum;
pub mod unsafe_scope;

use crate::allow;
use crate::source::Analysis;

/// Every rule an allow-marker may name.
pub const RULE_NAMES: &[&str] = &[
    "raw-accum",
    "no-panic-serve",
    "unsafe-scope",
    "lock-hygiene",
    "doc-gate",
];

/// One diagnostic: file, 1-based line, rule, message.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Finding {
    /// Display path (as given on the command line).
    pub path: String,
    /// 1-based source line.
    pub line: usize,
    /// Rule name (one of [`RULE_NAMES`] or `allow-marker`).
    pub rule: &'static str,
    /// Human-readable description with the suggested fix.
    pub msg: String,
}

impl Finding {
    /// `path:line: [rule] msg` — the grep/editor-clickable form.
    pub fn render(&self) -> String {
        format!("{}:{}: [{}] {}", self.path, self.line, self.rule,
                self.msg)
    }
}

/// Analyze one file: run every rule, subtract allow-marker grants, add
/// malformed-marker findings. `rel` uses `/` separators.
pub fn analyze(rel: &str, path: &str, src: &str) -> Vec<Finding> {
    let an = Analysis::of(src);
    let allows = allow::collect(&an, path);
    let mut out = allows.errors;
    let mut raw = Vec::new();
    raw.extend(raw_accum::run(rel, path, &an));
    raw.extend(no_panic_serve::run(rel, path, &an));
    raw.extend(unsafe_scope::run(rel, path, &an));
    raw.extend(lock_hygiene::run(rel, path, &an));
    raw.extend(doc_gate::run(rel, path, &an));
    for f in raw {
        if !allows.covers(f.line, f.rule) {
            out.push(f);
        }
    }
    out.sort();
    out
}

/// All byte offsets where `needle` occurs in `hay`.
pub fn find_all(hay: &str, needle: &str) -> Vec<usize> {
    let mut out = Vec::new();
    let mut from = 0;
    while let Some(p) = hay[from..].find(needle) {
        out.push(from + p);
        from += p + 1;
    }
    out
}

/// Does `rel` live under any of the given top-level dirs (each given
/// with a trailing slash)?
pub fn in_dirs(rel: &str, dirs: &[&str]) -> bool {
    dirs.iter().any(|d| rel.starts_with(d))
}
