//! Positive/negative fixtures for every rule, shared between the unit
//! tests (`cargo test -p salaad-lint`) and the CLI's `--self-check`
//! mode (run in CI before the tree scan, so a broken lexer can never
//! silently wave the real tree through).
//!
//! Each fixture is a (name, pseudo-relative-path, source, expected
//! findings) tuple; expectations are `(rule, line)` pairs and must
//! match exactly — extra or missing findings both fail.

use crate::rules::analyze;

/// One fixture: name, scan-relative path, source, expected
/// `(rule, 1-based line)` findings.
pub struct Fixture {
    /// Test name shown in self-check output.
    pub name: &'static str,
    /// Pseudo path relative to the scan root (drives rule scoping).
    pub rel: &'static str,
    /// Source text to lint.
    pub src: &'static str,
    /// Expected findings as `(rule, line)`, in any order.
    pub expect: &'static [(&'static str, usize)],
}

/// The full fixture set.
pub const FIXTURES: &[Fixture] = &[
    Fixture {
        name: "raw_accum_mul_loop_fires",
        rel: "slr/fake.rs",
        src: "//! Fixture.\n\
              fn dot(a: &[f32], b: &[f32]) -> f32 {\n\
              \x20   let mut acc = 0.0f32;\n\
              \x20   for i in 0..a.len() {\n\
              \x20       acc += a[i] * b[i];\n\
              \x20   }\n\
              \x20   acc\n\
              }\n",
        expect: &[("raw-accum", 5)],
    },
    Fixture {
        name: "raw_accum_bare_running_sum_fires",
        rel: "runtime/fake.rs",
        src: "//! Fixture.\n\
              fn total(xs: &[f32]) -> f32 {\n\
              \x20   let mut t = 0.0;\n\
              \x20   for x in xs {\n\
              \x20       t += x;\n\
              \x20   }\n\
              \x20   t\n\
              }\n",
        expect: &[("raw-accum", 5)],
    },
    Fixture {
        name: "raw_accum_sum_f32_and_fold_fire",
        rel: "tensor/fake.rs",
        src: "//! Fixture.\n\
              fn s(xs: &[f32]) -> f32 {\n\
              \x20   let a = xs.iter().sum::<f32>();\n\
              \x20   let b = xs.iter().fold(0.0, |u, v| u + v);\n\
              \x20   a + b\n\
              }\n",
        expect: &[("raw-accum", 3), ("raw-accum", 4)],
    },
    Fixture {
        name: "raw_accum_clean_shapes_pass",
        rel: "serve/fake.rs",
        src: "//! Fixture: counters, f64 widening, dot8 routing, and a\n\
              //! max-fold are all fine.\n\
              fn ok(a: &[f32], b: &[f32]) -> f64 {\n\
              \x20   let mut n = 0u64;\n\
              \x20   let mut acc = 0.0f64;\n\
              \x20   for i in 0..a.len() {\n\
              \x20       n += 1;\n\
              \x20       acc += a[i] as f64 * b[i] as f64;\n\
              \x20   }\n\
              \x20   let m = a.iter().copied().fold(f32::MIN, f32::max);\n\
              \x20   acc + n as f64 + m as f64\n\
              }\n",
        expect: &[],
    },
    Fixture {
        name: "raw_accum_test_code_exempt",
        rel: "slr/fake.rs",
        src: "//! Fixture.\n\
              #[cfg(test)]\n\
              mod tests {\n\
              \x20   fn naive(a: &[f32]) -> f32 {\n\
              \x20       let mut acc = 0.0;\n\
              \x20       for x in a {\n\
              \x20           acc += x;\n\
              \x20       }\n\
              \x20       acc\n\
              \x20   }\n\
              }\n",
        expect: &[],
    },
    Fixture {
        name: "raw_accum_allow_marker_with_reason",
        rel: "slr/fake.rs",
        src: "//! Fixture.\n\
              fn kernel(a: &[f32], b: &[f32]) -> f32 {\n\
              \x20   let mut acc = 0.0f32;\n\
              \x20   for i in 0..a.len() {\n\
              \x20       // salaad-lint: allow(raw-accum, reason = \
              \"normative kernel\")\n\
              \x20       acc += a[i] * b[i];\n\
              \x20   }\n\
              \x20   acc\n\
              }\n",
        expect: &[],
    },
    Fixture {
        name: "allow_marker_without_reason_is_a_finding",
        rel: "slr/fake.rs",
        src: "//! Fixture.\n\
              fn kernel(a: &[f32], b: &[f32]) -> f32 {\n\
              \x20   let mut acc = 0.0f32;\n\
              \x20   for i in 0..a.len() {\n\
              \x20       acc += a[i] * b[i]; // salaad-lint: \
              allow(raw-accum)\n\
              \x20   }\n\
              \x20   acc\n\
              }\n",
        expect: &[("allow-marker", 5), ("raw-accum", 5)],
    },
    Fixture {
        name: "allow_marker_unknown_rule_is_a_finding",
        rel: "slr/fake.rs",
        src: "//! Fixture.\n\
              // salaad-lint: allow(no-such-rule, reason = \"x\")\n\
              pub fn f() {}\n",
        expect: &[("allow-marker", 2), ("doc-gate", 3)],
    },
    Fixture {
        name: "no_panic_unwrap_fires_outside_tests",
        rel: "serve/fake.rs",
        src: "//! Fixture.\n\
              fn f(x: Option<u32>) -> u32 {\n\
              \x20   x.unwrap()\n\
              }\n\
              #[cfg(test)]\n\
              mod tests {\n\
              \x20   fn g(x: Option<u32>) -> u32 {\n\
              \x20       x.expect(\"test code is exempt\")\n\
              \x20   }\n\
              }\n",
        expect: &[("no-panic-serve", 3)],
    },
    Fixture {
        name: "no_panic_graceful_shapes_pass",
        rel: "runtime/fake.rs",
        src: "//! Fixture: unwrap_or and friends are graceful.\n\
              fn f(x: Option<u32>) -> u32 {\n\
              \x20   x.unwrap_or(0).max(x.unwrap_or_default())\n\
              }\n",
        expect: &[],
    },
    Fixture {
        name: "no_panic_macros_fire",
        rel: "serve/fake.rs",
        src: "//! Fixture.\n\
              fn f(ok: bool) {\n\
              \x20   if !ok {\n\
              \x20       panic!(\"boom\");\n\
              \x20   }\n\
              }\n",
        expect: &[("no-panic-serve", 4)],
    },
    Fixture {
        name: "no_panic_out_of_scope_dir_passes",
        rel: "util/fake.rs",
        src: "//! Fixture: util/ is outside the serving contract.\n\
              fn f(x: Option<u32>) -> u32 {\n\
              \x20   x.unwrap()\n\
              }\n",
        expect: &[],
    },
    Fixture {
        name: "unsafe_outside_whitelist_fires",
        rel: "runtime/other.rs",
        src: "//! Fixture.\n\
              fn f(p: *const u8) -> u8 {\n\
              \x20   unsafe { *p }\n\
              }\n",
        expect: &[("unsafe-scope", 3)],
    },
    Fixture {
        name: "unsafe_whitelisted_file_passes",
        rel: "runtime/literal.rs",
        src: "//! Fixture.\n\
              fn f(p: *const u8) -> u8 {\n\
              \x20   unsafe { *p }\n\
              }\n",
        expect: &[],
    },
    Fixture {
        name: "unsafe_simd_module_is_whitelisted",
        rel: "linalg/simd.rs",
        src: "//! Fixture: the AVX2 microkernel module is the second\n\
              //! sanctioned unsafe site.\n\
              fn f(p: *const f32) -> f32 {\n\
              \x20   unsafe { *p }\n\
              }\n",
        expect: &[],
    },
    Fixture {
        name: "unsafe_elsewhere_in_linalg_still_fires",
        rel: "linalg/matmul.rs",
        src: "//! Fixture: the whitelist is the simd module, not the\n\
              //! linalg directory.\n\
              fn f(p: *const f32) -> f32 {\n\
              \x20   unsafe { *p }\n\
              }\n",
        expect: &[("unsafe-scope", 4)],
    },
    Fixture {
        name: "lock_mutex_of_mut_fires",
        rel: "util/fake.rs",
        src: "//! Fixture.\n\
              fn f(out: &mut Vec<u32>) {\n\
              \x20   let m = std::sync::Mutex::new(&mut *out);\n\
              \x20   drop(m);\n\
              }\n",
        expect: &[("lock-hygiene", 3)],
    },
    Fixture {
        name: "lock_across_backend_call_fires",
        rel: "coordinator/fake.rs",
        src: "//! Fixture.\n\
              fn step(m: &std::sync::Mutex<u32>, b: &dyn B) {\n\
              \x20   let _g = m.lock();\n\
              \x20   b.decode_rows();\n\
              }\n",
        expect: &[("lock-hygiene", 3)],
    },
    Fixture {
        name: "lock_without_backend_call_passes",
        rel: "runtime/fake.rs",
        src: "//! Fixture: a cache guard with no backend call is fine.\n\
              fn get(m: &std::sync::Mutex<u32>) -> u32 {\n\
              \x20   *m.lock().unwrap_or_else(|p| p.into_inner())\n\
              }\n",
        expect: &[],
    },
    Fixture {
        name: "doc_gate_undocumented_pub_fires",
        rel: "slr/fake.rs",
        src: "//! Fixture.\n\
              pub struct S {\n\
              \x20   /// Documented field.\n\
              \x20   pub a: f32,\n\
              \x20   pub b: f32,\n\
              }\n\
              pub fn f() {}\n",
        expect: &[("doc-gate", 2), ("doc-gate", 5), ("doc-gate", 7)],
    },
    Fixture {
        name: "doc_gate_documented_and_exempt_pass",
        rel: "serve/fake.rs",
        src: "//! Fixture.\n\
              pub use std::time::Duration;\n\
              pub mod x {}\n\
              pub(crate) fn hidden() {}\n\
              /// Documented.\n\
              #[derive(Clone)]\n\
              pub struct S {\n\
              \x20   /// Documented.\n\
              \x20   pub a: f32,\n\
              }\n",
        expect: &[],
    },
    Fixture {
        name: "doc_gate_missing_module_doc_fires",
        rel: "linalg/fake.rs",
        src: "fn private_only() {}\n",
        expect: &[("doc-gate", 1)],
    },
    Fixture {
        name: "doc_gate_out_of_scope_dir_passes",
        rel: "cli/fake.rs",
        src: "pub fn undocumented_but_out_of_scope() {}\n",
        expect: &[],
    },
    Fixture {
        name: "doc_gate_covers_control_plane_surface",
        rel: "serve/autoscale.rs",
        src: "//! Fixture: the elasticity control surface is inside\n\
              //! the doc gate — a bare command enum or an\n\
              //! undocumented accessor on the controller fires.\n\
              pub enum Cmd {\n\
              \x20   Admit { frac: f64 },\n\
              }\n\
              /// Documented.\n\
              pub struct Ctl;\n\
              impl Ctl {\n\
              \x20   pub fn level(&self) -> usize {\n\
              \x20       0\n\
              \x20   }\n\
              }\n",
        expect: &[("doc-gate", 4), ("doc-gate", 10)],
    },
    Fixture {
        name: "speculate_path_violations_fire",
        rel: "serve/speculate.rs",
        src: "//! Fixture: the speculative-decode path sits inside both\n\
              //! the raw-accum and no-panic-serve contracts.\n\
              fn verify(logits: &[f32], k: Option<usize>) -> f32 {\n\
              \x20   let n = k.unwrap();\n\
              \x20   let mut acc = 0.0f32;\n\
              \x20   for i in 0..n {\n\
              \x20       acc += logits[i] * logits[i];\n\
              \x20   }\n\
              \x20   acc\n\
              }\n",
        expect: &[("no-panic-serve", 4), ("raw-accum", 7)],
    },
    Fixture {
        name: "speculate_path_clean_shapes_pass",
        rel: "serve/speculate.rs",
        src: "//! Fixture: the shapes the real speculate.rs uses — u64\n\
              //! counters and an agreeing-prefix scan — stay clean.\n\
              fn accept(drafts: &[i32], masters: &[i32],\n\
              \x20         drafted: &mut u64) -> usize {\n\
              \x20   *drafted += drafts.len() as u64;\n\
              \x20   drafts.iter().zip(masters)\n\
              \x20       .take_while(|(d, m)| d == m)\n\
              \x20       .count()\n\
              }\n",
        expect: &[],
    },
];

/// Run one fixture; returns a list of mismatch descriptions (empty on
/// pass).
pub fn check_fixture(f: &Fixture) -> Vec<String> {
    let got = analyze(f.rel, f.rel, f.src);
    let mut got_pairs: Vec<(&str, usize)> =
        got.iter().map(|g| (g.rule, g.line)).collect();
    got_pairs.sort();
    let mut want: Vec<(&str, usize)> = f.expect.to_vec();
    want.sort();
    let mut errs = Vec::new();
    if got_pairs != want {
        errs.push(format!(
            "{}: expected {:?}, got {:?}",
            f.name,
            want,
            got.iter().map(|g| g.render()).collect::<Vec<_>>()
        ));
    }
    errs
}

/// Run every fixture; returns all mismatches.
pub fn self_check() -> Vec<String> {
    let mut errs = Vec::new();
    for f in FIXTURES {
        errs.extend(check_fixture(f));
    }
    errs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_fixtures_pass() {
        let errs = self_check();
        assert!(errs.is_empty(), "{}", errs.join("\n"));
    }
}
