//! The allow-marker protocol: `// salaad-lint: allow(<rule>, reason =
//! "...")` suppresses one rule on one line of code.
//!
//! A trailing marker (code before the comment on the same line)
//! applies to that line; a standalone marker line applies to the next
//! line that contains actual code — blank and comment-only lines
//! (including doc comments) are skipped on the way down. A marker with an
//! unknown rule name, or a missing/empty reason, is itself a finding —
//! the CI gate treats reason-less suppressions as violations.

use crate::rules::{Finding, RULE_NAMES};
use crate::source::Analysis;

/// Parsed suppression table plus the findings produced by malformed
/// markers themselves.
pub struct Allows {
    /// `(1-based line, rule)` pairs that are suppressed.
    granted: Vec<(usize, &'static str)>,
    /// Malformed-marker findings (`allow-marker` rule).
    pub errors: Vec<Finding>,
}

impl Allows {
    /// Is `rule` suppressed on `line` (1-based)?
    pub fn covers(&self, line: usize, rule: &str) -> bool {
        self.granted.iter().any(|&(l, r)| l == line && r == rule)
    }
}

/// Scan every line comment of `an` for markers; resolve each to its
/// target line.
pub fn collect(an: &Analysis, path: &str) -> Allows {
    let mut granted = Vec::new();
    let mut errors = Vec::new();
    for c in &an.comments {
        let Some(at) = c.text.find("salaad-lint:") else { continue };
        let line = an.line_of(c.start);
        let rest = c.text[at + "salaad-lint:".len()..].trim_start();
        match parse_marker(rest) {
            Ok(rule) => {
                let target = target_line(an, c.start, line);
                granted.push((target, rule));
            }
            Err(msg) => errors.push(Finding {
                path: path.to_string(),
                line,
                rule: "allow-marker",
                msg,
            }),
        }
    }
    Allows { granted, errors }
}

/// Parse `allow(<rule>, reason = "...")`. Returns the (static) rule
/// name or an error message describing what is malformed.
fn parse_marker(rest: &str) -> Result<&'static str, String> {
    let Some(args) = rest.strip_prefix("allow(") else {
        return Err("marker must be `allow(<rule>, reason = \"...\")`"
            .to_string());
    };
    let Some(close) = args.rfind(')') else {
        return Err("unclosed allow(...) marker".to_string());
    };
    let args = &args[..close];
    let (rule_txt, reason_txt) = match args.find(',') {
        Some(comma) => (args[..comma].trim(), Some(args[comma + 1..].trim())),
        None => (args.trim(), None),
    };
    let Some(rule) = RULE_NAMES.iter().copied().find(|r| *r == rule_txt)
    else {
        return Err(format!(
            "unknown rule `{rule_txt}` in allow marker (expected one \
             of: {})",
            RULE_NAMES.join(", ")
        ));
    };
    let Some(reason) = reason_txt else {
        return Err(format!(
            "allow({rule}) marker is missing its reason — every \
             suppression must say why (reason = \"...\")"
        ));
    };
    let Some(q) = reason.strip_prefix("reason") else {
        return Err(format!(
            "allow({rule}): expected `reason = \"...\"` after the rule"
        ));
    };
    let q = q.trim_start();
    let Some(q) = q.strip_prefix('=') else {
        return Err(format!(
            "allow({rule}): expected `reason = \"...\"` after the rule"
        ));
    };
    let q = q.trim();
    let body = q
        .strip_prefix('"')
        .and_then(|s| s.strip_suffix('"'))
        .unwrap_or(q);
    if body.trim().is_empty() {
        return Err(format!(
            "allow({rule}) marker has an empty reason — every \
             suppression must say why"
        ));
    }
    Ok(rule)
}

/// Resolve a marker to the 1-based line it suppresses.
fn target_line(an: &Analysis, comment_start: usize, line: usize) -> usize {
    let (ls, _) = an.line_span(comment_start);
    let before = &an.masked[ls..comment_start];
    if !before.trim().is_empty() {
        return line; // trailing marker
    }
    // Standalone: first following line with real (masked) code.
    let mut l = line; // 1-based current line index → 0-based next is `line`
    while l < an.line_start.len() {
        let start = an.line_start[l];
        let end = if l + 1 < an.line_start.len() {
            an.line_start[l + 1] - 1
        } else {
            an.masked.len()
        };
        if !an.masked[start..end.min(an.masked.len())].trim().is_empty() {
            return l + 1;
        }
        l += 1;
    }
    line
}
