//! Lossless-enough lexical analysis of a Rust source file.
//!
//! `salaad-lint` deliberately does not parse Rust. It builds a *masked*
//! view of the source — string/char literals and comments blanked out,
//! everything else byte-for-byte in place — plus a handful of per-byte
//! structural maps (test regions, loop-nesting depth, `fn` body spans)
//! that the rules in [`crate::rules`] pattern-match against. This keeps
//! the pass dependency-free (the container that grows this repo has no
//! network, so `syn` is off the table) and fast enough to run on every
//! `cargo test`.
//!
//! The masking lexer understands: line comments, nested block comments,
//! string literals (including `r#"…"#` raw strings and `b"…"` byte
//! strings), char/byte-char literals vs. lifetimes, and preserves
//! newlines so byte offsets map to line numbers. Non-ASCII characters
//! (which in this tree occur only inside comments and strings) are
//! blanked as well, so the masked text is pure ASCII and byte offsets
//! are character offsets.

/// One `//…` line comment: its byte offset in the source and its raw
/// text (including the leading slashes). Allow-markers are parsed from
/// these; block comments are blanked and dropped.
pub struct Comment {
    /// Byte offset of the first `/` in the (masked) source.
    pub start: usize,
    /// Raw comment text up to, not including, the newline.
    pub text: String,
}

/// Structural view of one source file. All vectors indexed by byte
/// offset into `masked` are exactly `masked.len()` long.
pub struct Analysis {
    /// Source with comments/strings blanked; same length as the input.
    pub masked: String,
    /// Original source split into lines (for doc-comment checks).
    pub raw_lines: Vec<String>,
    /// Byte offset of the start of each line in `masked`.
    pub line_start: Vec<usize>,
    /// Per byte: inside a `#[cfg(test)]`/`#[test]` item?
    pub is_test: Vec<bool>,
    /// Per byte: number of enclosing `for`/`while`/`loop` bodies.
    pub loop_depth: Vec<u16>,
    /// `(open_brace, close_brace)` byte offsets of every `fn` body.
    pub fn_bodies: Vec<(usize, usize)>,
    /// All `//` line comments, in source order.
    pub comments: Vec<Comment>,
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

impl Analysis {
    /// Run the masking lexer and the structural passes over `src`.
    pub fn of(src: &str) -> Analysis {
        let (masked, comments) = mask(src);
        let b = masked.as_bytes();
        let n = b.len();
        let mut line_start = vec![0usize];
        let mut i = 0;
        while i < n {
            if b[i] == b'\n' {
                line_start.push(i + 1);
            }
            i += 1;
        }
        let is_test = test_regions(&masked);
        let (loop_depth, fn_bodies) = structure(&masked);
        Analysis {
            masked,
            raw_lines: src.lines().map(|l| l.to_string()).collect(),
            line_start,
            is_test,
            loop_depth,
            fn_bodies,
            comments,
        }
    }

    /// 1-based line number of a byte offset.
    pub fn line_of(&self, off: usize) -> usize {
        match self.line_start.binary_search(&off) {
            Ok(l) => l + 1,
            Err(l) => l,
        }
    }

    /// 0-based byte range `[start, end)` of the line containing `off`
    /// (not including the newline).
    pub fn line_span(&self, off: usize) -> (usize, usize) {
        let l = self.line_of(off) - 1;
        let start = self.line_start[l];
        let end = if l + 1 < self.line_start.len() {
            self.line_start[l + 1] - 1
        } else {
            self.masked.len()
        };
        (start, end)
    }

    /// Innermost `fn` body containing `off`, if any.
    pub fn enclosing_fn(&self, off: usize) -> Option<(usize, usize)> {
        self.fn_bodies
            .iter()
            .copied()
            .filter(|&(o, c)| o < off && off < c)
            .min_by_key(|&(o, c)| c - o)
    }
}

/// Blank out comments, strings, and char literals; collect line
/// comments. The returned string has the same byte length as `src`
/// would after replacing every non-ASCII char with a space (the lexer
/// operates on chars and emits one ASCII byte per char).
fn mask(src: &str) -> (String, Vec<Comment>) {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut out = vec![b' '; n];
    let mut comments = Vec::new();
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            out[i] = b'\n';
            i += 1;
        } else if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let start = i;
            while i < n && chars[i] != '\n' {
                i += 1;
            }
            let text: String = chars[start..i].iter().collect();
            comments.push(Comment { start, text });
        } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1;
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/'
                {
                    depth -= 1;
                    i += 2;
                } else {
                    if chars[i] == '\n' {
                        out[i] = b'\n';
                    }
                    i += 1;
                }
            }
        } else if c == '"' {
            i = skip_plain_string(&chars, i, &mut out);
        } else if c == 'r'
            && !(i > 0 && chars[i - 1].is_ascii_alphanumeric()
                 || i > 0 && chars[i - 1] == '_')
            && raw_string_hashes(&chars, i + 1).is_some()
        {
            let hashes = raw_string_hashes(&chars, i + 1).unwrap_or(0);
            out[i] = b'r';
            i = skip_raw_string(&chars, i + 1, hashes, &mut out);
        } else if c == 'b'
            && !(i > 0 && (chars[i - 1].is_ascii_alphanumeric()
                           || chars[i - 1] == '_'))
            && i + 1 < n
        {
            out[i] = b'b';
            if chars[i + 1] == '"' {
                i = skip_plain_string(&chars, i + 1, &mut out);
            } else if chars[i + 1] == '\'' {
                i = skip_char_literal(&chars, i + 1, &mut out);
            } else if chars[i + 1] == 'r'
                && raw_string_hashes(&chars, i + 2).is_some()
            {
                let hashes = raw_string_hashes(&chars, i + 2).unwrap_or(0);
                out[i + 1] = b'r';
                i = skip_raw_string(&chars, i + 2, hashes, &mut out);
            } else {
                i += 1;
            }
        } else if c == '\'' {
            if is_char_literal(&chars, i) {
                i = skip_char_literal(&chars, i, &mut out);
            } else {
                // Lifetime tick: keep as code.
                out[i] = b'\'';
                i += 1;
            }
        } else {
            out[i] = if c.is_ascii() { c as u8 } else { b' ' };
            i += 1;
        }
    }
    // SAFETY-free: `out` is all ASCII by construction.
    (String::from_utf8_lossy(&out).into_owned(), comments)
}

/// Number of `#`s if `chars[at..]` begins a raw-string opener
/// (`#*"`), else None.
fn raw_string_hashes(chars: &[char], at: usize) -> Option<usize> {
    let mut j = at;
    while j < chars.len() && chars[j] == '#' {
        j += 1;
    }
    if j < chars.len() && chars[j] == '"' {
        Some(j - at)
    } else {
        None
    }
}

/// Skip a `"…"` literal starting at the opening quote; keeps the
/// quotes in the mask (content blanked, newlines preserved). Returns
/// the index just past the closing quote.
fn skip_plain_string(chars: &[char], open: usize, out: &mut [u8]) -> usize {
    out[open] = b'"';
    let mut i = open + 1;
    while i < chars.len() {
        match chars[i] {
            // Escapes, including the `\<newline>` string continuation:
            // the newline must survive masking or every later line
            // number drifts.
            '\\' => {
                if i + 1 < chars.len() && chars[i + 1] == '\n' {
                    out[i + 1] = b'\n';
                }
                i += 2;
            }
            '"' => {
                out[i] = b'"';
                return i + 1;
            }
            '\n' => {
                out[i] = b'\n';
                i += 1;
            }
            _ => i += 1,
        }
    }
    i
}

/// Skip `#*"…"#*` starting at the first `#` (or the quote); `hashes`
/// is the opener's hash count. Returns the index past the closer.
fn skip_raw_string(chars: &[char], at: usize, hashes: usize,
                   out: &mut [u8]) -> usize {
    let mut i = at;
    // Opener: hashes then quote.
    while i < chars.len() && chars[i] == '#' {
        out[i] = b'#';
        i += 1;
    }
    if i < chars.len() {
        out[i] = b'"';
        i += 1;
    }
    while i < chars.len() {
        if chars[i] == '"' {
            let mut k = 0;
            while k < hashes
                && i + 1 + k < chars.len()
                && chars[i + 1 + k] == '#'
            {
                k += 1;
            }
            if k == hashes {
                out[i] = b'"';
                for slot in out.iter_mut().skip(i + 1).take(hashes) {
                    *slot = b'#';
                }
                return i + 1 + hashes;
            }
        }
        if chars[i] == '\n' {
            out[i] = b'\n';
        }
        i += 1;
    }
    i
}

/// Is the `'` at `at` the start of a char literal (vs. a lifetime)?
fn is_char_literal(chars: &[char], at: usize) -> bool {
    if at + 1 >= chars.len() {
        return false;
    }
    if chars[at + 1] == '\\' {
        return true;
    }
    at + 2 < chars.len() && chars[at + 2] == '\'' && chars[at + 1] != '\''
}

/// Skip a char/byte-char literal starting at the opening tick.
/// Handles escapes including `'\u{…}'`. Returns the index past the
/// closing tick.
fn skip_char_literal(chars: &[char], open: usize, out: &mut [u8]) -> usize {
    let mut i = open + 1;
    if i < chars.len() && chars[i] == '\\' {
        i += 2; // skip the escape lead; scan to the closing tick
        while i < chars.len() && chars[i] != '\'' && i - open < 12 {
            i += 1;
        }
    } else if i < chars.len() {
        i += 1;
    }
    if i < chars.len() && chars[i] == '\'' {
        return i + 1;
    }
    // Malformed / not actually a literal: emit the tick and move on.
    out[open] = b'\'';
    open + 1
}

/// Mark the byte ranges covered by `#[cfg(test)] …` / `#[test] …`
/// items (attribute through the matching close brace, or the
/// terminating semicolon).
fn test_regions(masked: &str) -> Vec<bool> {
    let b = masked.as_bytes();
    let n = b.len();
    let mut out = vec![false; n];
    let mut from = 0;
    loop {
        let Some(p) = masked[from..].find("#[") else { break };
        let attr_start = from + p;
        // Bracket-balanced attribute body.
        let mut depth = 0i32;
        let mut j = attr_start + 1;
        let mut attr_end = n;
        while j < n {
            match b[j] {
                b'[' => depth += 1,
                b']' => {
                    depth -= 1;
                    if depth == 0 {
                        attr_end = j + 1;
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
        from = attr_end.min(n);
        let body = &masked[attr_start + 2..attr_end.saturating_sub(1)];
        if !attr_is_test(body) {
            continue;
        }
        // Item extent: first `;` or brace-matched `{…}` at
        // paren/bracket depth 0 after the attribute.
        let mut pd = 0i32;
        let mut k = attr_end;
        let mut item_end = n;
        while k < n {
            match b[k] {
                b'(' | b'[' => pd += 1,
                b')' | b']' => pd -= 1,
                b';' if pd == 0 => {
                    item_end = k + 1;
                    break;
                }
                b'{' if pd == 0 => {
                    item_end = match_brace(b, k);
                    break;
                }
                _ => {}
            }
            k += 1;
        }
        for slot in out.iter_mut().take(item_end).skip(attr_start) {
            *slot = true;
        }
        from = item_end.max(from);
    }
    out
}

/// Does an attribute body (text between `#[` and `]`) gate on test?
/// Accepts `test` and `cfg(… test …)`; rejects `cfg_attr(…)` and
/// `cfg(not(test))` is out of scope for this tree (checked absent).
fn attr_is_test(body: &str) -> bool {
    let t = body.trim();
    if t == "test" {
        return true;
    }
    let Some(rest) = t.strip_prefix("cfg") else { return false };
    if !rest.trim_start().starts_with('(') {
        return false;
    }
    contains_word(rest, "test")
}

/// Word-boundary substring search.
pub fn contains_word(hay: &str, word: &str) -> bool {
    let b = hay.as_bytes();
    let mut from = 0;
    while let Some(p) = hay[from..].find(word) {
        let at = from + p;
        let pre_ok = at == 0 || !is_ident(b[at - 1]);
        let end = at + word.len();
        let post_ok = end >= b.len() || !is_ident(b[end]);
        if pre_ok && post_ok {
            return true;
        }
        from = at + 1;
    }
    false
}

/// Index just past the `}` matching the `{` at `open` (or `len` if
/// unbalanced).
fn match_brace(b: &[u8], open: usize) -> usize {
    let mut depth = 0i32;
    let mut i = open;
    while i < b.len() {
        match b[i] {
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return i + 1;
                }
            }
            _ => {}
        }
        i += 1;
    }
    b.len()
}

/// One pass over the masked text computing per-byte loop depth and
/// `fn` body spans. A `{` opens a loop body iff the preceding control
/// keyword resolved to a loop: `while`/`loop` directly, `for` only if
/// an `in` follows it before the brace (so `impl Trait for Type {`
/// does not count).
fn structure(masked: &str) -> (Vec<u16>, Vec<(usize, usize)>) {
    #[derive(PartialEq)]
    enum Pending {
        None,
        ForSeen,
        LoopPending,
    }
    let b = masked.as_bytes();
    let n = b.len();
    let mut depth_at = vec![0u16; n];
    let mut fn_bodies = Vec::new();
    let mut brace_stack: Vec<(bool, bool, usize)> = Vec::new();
    let mut cur_depth = 0u16;
    let mut paren = 0i32;
    let mut pending = Pending::None;
    let mut fn_pending = false;
    let mut i = 0;
    while i < n {
        if i < depth_at.len() {
            depth_at[i] = cur_depth;
        }
        let c = b[i];
        if is_ident(c) && (i == 0 || !is_ident(b[i - 1])) {
            let mut j = i + 1;
            while j < n && is_ident(b[j]) {
                j += 1;
            }
            match &masked[i..j] {
                "for" => pending = Pending::ForSeen,
                "while" | "loop" => pending = Pending::LoopPending,
                "in" if pending == Pending::ForSeen => {
                    pending = Pending::LoopPending
                }
                "fn" => fn_pending = true,
                _ => {}
            }
            for slot in depth_at.iter_mut().take(j).skip(i) {
                *slot = cur_depth;
            }
            i = j;
            continue;
        }
        match c {
            b'(' | b'[' => paren += 1,
            b')' | b']' => paren -= 1,
            b';' if paren == 0 => {
                pending = Pending::None;
                fn_pending = false;
            }
            b'{' => {
                let is_loop = pending == Pending::LoopPending && paren == 0;
                let is_fn = fn_pending && paren == 0;
                brace_stack.push((is_loop, is_fn, i));
                if is_loop {
                    cur_depth += 1;
                }
                if is_fn {
                    fn_pending = false;
                }
                pending = Pending::None;
            }
            b'}' => {
                if let Some((was_loop, was_fn, open)) = brace_stack.pop() {
                    if was_loop {
                        cur_depth = cur_depth.saturating_sub(1);
                    }
                    if was_fn {
                        fn_bodies.push((open, i));
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
    (depth_at, fn_bodies)
}
